//! Service metrics: requests, bits, simulated vs wall time, utilization.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::stats::Summary;

#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub chunks: AtomicU64,
    pub result_bits: AtomicU64,
    pub aaps: AtomicU64,
    /// simulated DRAM nanoseconds (batched wave time)
    pub sim_ns: AtomicU64,
    /// host nanoseconds spent in workers
    pub wall_ns: AtomicU64,
    /// waves issued by executed wave sets
    pub waves: AtomicU64,
    /// row slots that carried a chunk across those waves
    pub wave_slots_filled: AtomicU64,
    /// row slots the issued waves exposed (waves × wave_slots)
    pub wave_slots_total: AtomicU64,
    latency: Mutex<Summary>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    pub fn record_request(&self, result_bits: u64, chunks: u64, aaps: u64) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.result_bits.fetch_add(result_bits, Ordering::Relaxed);
        self.chunks.fetch_add(chunks, Ordering::Relaxed);
        self.aaps.fetch_add(aaps, Ordering::Relaxed);
    }

    pub fn record_sim_ns(&self, ns: f64) {
        self.sim_ns.fetch_add(ns as u64, Ordering::Relaxed);
    }

    /// Account one executed wave set (solo request or coalesced batch):
    /// how many waves it issued, how many row slots they exposed, and how
    /// many carried a chunk. Recorded at submission time — the wave plan
    /// is fixed the moment the set is scheduled.
    pub fn record_waves(&self, waves: u64, slots_filled: u64, slots_total: u64) {
        self.waves.fetch_add(waves, Ordering::Relaxed);
        self.wave_slots_filled.fetch_add(slots_filled, Ordering::Relaxed);
        self.wave_slots_total.fetch_add(slots_total, Ordering::Relaxed);
    }

    pub fn record_wall_ns(&self, ns: u64) {
        self.wall_ns.fetch_add(ns, Ordering::Relaxed);
    }

    pub fn record_latency_ns(&self, ns: f64) {
        self.latency.lock().unwrap().add(ns);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let lat = self.latency.lock().unwrap();
        let sim_ns = self.sim_ns.load(Ordering::Relaxed);
        let bits = self.result_bits.load(Ordering::Relaxed);
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            chunks: self.chunks.load(Ordering::Relaxed),
            result_bits: bits,
            aaps: self.aaps.load(Ordering::Relaxed),
            sim_ns,
            wall_ns: self.wall_ns.load(Ordering::Relaxed),
            waves: self.waves.load(Ordering::Relaxed),
            wave_slots_filled: self.wave_slots_filled.load(Ordering::Relaxed),
            wave_slots_total: self.wave_slots_total.load(Ordering::Relaxed),
            mean_latency_ns: lat.mean(),
            max_latency_ns: if lat.count() > 0 { lat.max() } else { 0.0 },
            sim_throughput_bits_per_sec: if sim_ns > 0 {
                bits as f64 / (sim_ns as f64 * 1e-9)
            } else {
                0.0
            },
        }
    }
}

#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub chunks: u64,
    pub result_bits: u64,
    pub aaps: u64,
    pub sim_ns: u64,
    pub wall_ns: u64,
    /// waves issued by executed wave sets
    pub waves: u64,
    /// row slots that carried a chunk across those waves
    pub wave_slots_filled: u64,
    /// row slots the issued waves exposed
    pub wave_slots_total: u64,
    pub mean_latency_ns: f64,
    pub max_latency_ns: f64,
    pub sim_throughput_bits_per_sec: f64,
}

impl MetricsSnapshot {
    /// Fraction of exposed wave row slots that carried work (0..1). A
    /// device that issued no waves is vacuously fully occupied — the
    /// counters viewed as one aggregate [`super::router::WavePlan`], so
    /// the convention stays defined in exactly one place.
    pub fn slot_occupancy(&self) -> f64 {
        super::router::WavePlan {
            waves: self.waves,
            slots_filled: self.wave_slots_filled,
            slots_total: self.wave_slots_total,
        }
        .occupancy()
    }

    pub fn report(&self) -> String {
        use crate::util::stats::{fmt_ns, fmt_rate};
        format!(
            "requests: {}  chunks: {}  result bits: {}  AAPs: {}\n\
             simulated time: {}  (throughput {}bit/s)\n\
             waves: {}  slot occupancy: {:.1}%\n\
             host wall time: {}  mean sim latency: {}  max: {}",
            self.requests,
            self.chunks,
            self.result_bits,
            self.aaps,
            fmt_ns(self.sim_ns as f64),
            fmt_rate(self.sim_throughput_bits_per_sec),
            self.waves,
            100.0 * self.slot_occupancy(),
            fmt_ns(self.wall_ns as f64),
            fmt_ns(self.mean_latency_ns),
            fmt_ns(self.max_latency_ns),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_request(8192, 1, 3);
        m.record_request(8192, 1, 3);
        m.record_sim_ns(540.0);
        m.record_latency_ns(270.0);
        m.record_latency_ns(810.0);
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.result_bits, 16384);
        assert_eq!(s.aaps, 6);
        assert!((s.mean_latency_ns - 540.0).abs() < 1e-9);
        assert!(s.sim_throughput_bits_per_sec > 0.0);
        assert!(s.report().contains("requests: 2"));
    }

    #[test]
    fn empty_snapshot_is_sane() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.sim_throughput_bits_per_sec, 0.0);
        // no waves issued → vacuously fully occupied (utilization convention)
        assert_eq!(s.waves, 0);
        assert!((s.slot_occupancy() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn wave_counters_accumulate_into_occupancy() {
        let m = Metrics::new();
        // one full wave of 4 slots, then a lone chunk in its own wave
        m.record_waves(1, 4, 4);
        m.record_waves(1, 1, 4);
        let s = m.snapshot();
        assert_eq!(s.waves, 2);
        assert_eq!(s.wave_slots_filled, 5);
        assert_eq!(s.wave_slots_total, 8);
        assert!((s.slot_occupancy() - 0.625).abs() < 1e-12);
        assert!(s.report().contains("slot occupancy"), "{}", s.report());
    }
}
