//! DRIM's AAP instruction set (paper §3.2).
//!
//! Four AAP (ACTIVATE-ACTIVATE-PRECHARGE) instruction types, differing only
//! in the number of activated source/destination rows:
//!
//! * type-1 `AAP(src, des)`            — copy / NOT (via DCC word-lines)
//! * type-2 `AAP(src, des1, des2)`     — double-copy
//! * type-3 `AAP(src1, src2, des)`     — DRA → X(N)OR2
//! * type-4 `AAP(src1, src2, src3, des)` — TRA → MAJ3
//!
//! `Program` is a straight-line sequence of AAPs operating inside one
//! sub-array (the unit the coordinator schedules); `programs` builds the
//! Table 2 micro-programs.
#![warn(missing_docs)]

pub mod assemble;
pub mod program;

use crate::dram::command::{AapKind, RowId};

/// One AAP instruction. The vector length (`size` in the paper's ISA) is
/// carried by the enclosing `Program`; every AAP moves a full row.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum AapInstr {
    /// type-1: single-source copy (also NOT, via a DCC word-line)
    Aap1 {
        /// source row
        src: RowId,
        /// destination row
        des: RowId,
    },
    /// type-2: double-copy (one source restored into two destinations)
    Aap2 {
        /// source row
        src: RowId,
        /// both destination rows
        des: [RowId; 2],
    },
    /// type-3: dual-row activation → X(N)OR2 of the two sources
    Aap3 {
        /// the two simultaneously activated source rows
        src: [RowId; 2],
        /// destination row
        des: RowId,
    },
    /// type-4: triple-row activation → MAJ3 of the three sources
    Aap4 {
        /// the three simultaneously activated source rows
        src: [RowId; 3],
        /// destination row
        des: RowId,
    },
}

impl AapInstr {
    /// The DRAM command-level kind of this instruction (copy / double-copy
    /// / DRA / TRA) — what timing and energy accounting key off.
    pub fn kind(&self) -> AapKind {
        match self {
            AapInstr::Aap1 { .. } => AapKind::Copy,
            AapInstr::Aap2 { .. } => AapKind::DoubleCopy,
            AapInstr::Aap3 { .. } => AapKind::Dra,
            AapInstr::Aap4 { .. } => AapKind::Tra,
        }
    }

    /// Source rows, in activation order.
    pub fn sources(&self) -> Vec<RowId> {
        match self {
            AapInstr::Aap1 { src, .. } | AapInstr::Aap2 { src, .. } => vec![*src],
            AapInstr::Aap3 { src, .. } => src.to_vec(),
            AapInstr::Aap4 { src, .. } => src.to_vec(),
        }
    }

    /// Destination rows.
    pub fn dests(&self) -> Vec<RowId> {
        match self {
            AapInstr::Aap1 { des, .. }
            | AapInstr::Aap3 { des, .. }
            | AapInstr::Aap4 { des, .. } => vec![*des],
            AapInstr::Aap2 { des, .. } => des.to_vec(),
        }
    }

    /// Total simultaneously-activated word-lines in the wider of the two
    /// activation phases (for reliability/energy accounting).
    pub fn max_parallel_rows(&self) -> usize {
        self.kind().source_rows().max(self.kind().dest_rows())
    }
}

impl std::fmt::Display for AapInstr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s: Vec<String> = self
            .sources()
            .iter()
            .chain(self.dests().iter())
            .map(|r| r.to_string())
            .collect();
        write!(f, "AAP({})", s.join(", "))
    }
}

/// A straight-line AAP program addressed within one sub-array.
#[derive(Clone, Debug, PartialEq)]
pub struct Program {
    /// Human-readable name (the Table 2 row it implements).
    pub name: String,
    /// The instruction sequence, executed in order.
    pub instrs: Vec<AapInstr>,
}

impl Program {
    /// Empty program with the given name.
    pub fn new(name: impl Into<String>) -> Self {
        Program {
            name: name.into(),
            instrs: Vec::new(),
        }
    }

    /// Append one instruction (builder-style).
    pub fn push(&mut self, i: AapInstr) -> &mut Self {
        self.instrs.push(i);
        self
    }

    /// Number of AAP primitives (the paper's command-sequence cost unit).
    pub fn aap_count(&self) -> usize {
        self.instrs.len()
    }

    /// Latency on the given timing model (straight-line, no overlap — AAPs
    /// within one sub-array serialize on the shared SA row).
    pub fn duration_ns(&self, t: &crate::dram::timing::TimingParams) -> f64 {
        t.seq_ns(self.aap_count())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::command::RowId::*;

    #[test]
    fn instr_accessors() {
        let i = AapInstr::Aap3 {
            src: [X(1), X(2)],
            des: Data(5),
        };
        assert_eq!(i.kind(), AapKind::Dra);
        assert_eq!(i.sources(), vec![X(1), X(2)]);
        assert_eq!(i.dests(), vec![Data(5)]);
        assert_eq!(i.max_parallel_rows(), 2);
        assert_eq!(i.to_string(), "AAP(x1, x2, d5)");
    }

    #[test]
    fn program_duration() {
        let t = crate::dram::timing::TimingParams::default();
        let mut p = Program::new("p");
        p.push(AapInstr::Aap1 {
            src: Data(0),
            des: X(1),
        });
        p.push(AapInstr::Aap1 {
            src: Data(1),
            des: X(2),
        });
        assert_eq!(p.aap_count(), 2);
        assert_eq!(p.duration_ns(&t), 180.0);
    }
}
