//! Text assembler / disassembler for AAP programs.
//!
//! Format: one instruction per line, `AAP(op1, op2[, op3[, op4]])` with row
//! names `d<N>` (data), `x<N>` (computation), `dcc<N>` (dual-contact
//! word-line). Comments start with `#`. The instruction type is inferred
//! from arity, matching the paper's ISA (§3.2): 2 operands → type-1,
//! 3 operands → type-2 vs type-3 is ambiguous, so type-2 is written as
//! `AAP2(...)` and type-3 as `AAP(...)`; type-4 has 4 operands.

use crate::dram::command::RowId;

use super::{AapInstr, Program};

/// Render a program in the text format (`# program:` header + one
/// instruction per line); round-trips through [`parse_program`].
pub fn format_program(p: &Program) -> String {
    let mut out = format!("# program: {} ({} AAPs)\n", p.name, p.aap_count());
    for i in &p.instrs {
        out.push_str(&format_instr(i));
        out.push('\n');
    }
    out
}

/// Render one instruction (`AAP2(...)` for type-2, `AAP(...)` otherwise).
pub fn format_instr(i: &AapInstr) -> String {
    match i {
        AapInstr::Aap2 { src, des } => format!("AAP2({src}, {}, {})", des[0], des[1]),
        _ => i.to_string(),
    }
}

/// Why a line failed to assemble.
#[derive(Debug, PartialEq)]
pub enum ParseError {
    /// Not of the form `AAP(...)` / `AAP2(...)`.
    BadSyntax(String),
    /// An operand is not a valid row name (`d<N>`, `x<N>`, `dcc<N>`).
    BadRow(String),
    /// Operand count matches no AAP type.
    BadArity(usize),
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ParseError::BadSyntax(l) => write!(f, "syntax error: {l:?}"),
            ParseError::BadRow(r) => write!(f, "bad row name: {r:?}"),
            ParseError::BadArity(n) => write!(f, "bad operand count: {n}"),
        }
    }
}

/// Parse one instruction line (see the module docs for the format).
pub fn parse_instr(line: &str) -> Result<AapInstr, ParseError> {
    let line = line.trim();
    let (head, rest) = line
        .split_once('(')
        .ok_or_else(|| ParseError::BadSyntax(line.into()))?;
    let body = rest
        .strip_suffix(')')
        .ok_or_else(|| ParseError::BadSyntax(line.into()))?;
    let is_type2 = match head.trim() {
        "AAP" => false,
        "AAP2" => true,
        _ => return Err(ParseError::BadSyntax(line.into())),
    };
    let rows: Vec<RowId> = body
        .split(',')
        .map(|t| {
            let t = t.trim();
            RowId::parse(t).ok_or_else(|| ParseError::BadRow(t.into()))
        })
        .collect::<Result<_, _>>()?;
    match (rows.len(), is_type2) {
        (2, false) => Ok(AapInstr::Aap1 {
            src: rows[0],
            des: rows[1],
        }),
        (3, true) => Ok(AapInstr::Aap2 {
            src: rows[0],
            des: [rows[1], rows[2]],
        }),
        (3, false) => Ok(AapInstr::Aap3 {
            src: [rows[0], rows[1]],
            des: rows[2],
        }),
        (4, false) => Ok(AapInstr::Aap4 {
            src: [rows[0], rows[1], rows[2]],
            des: rows[3],
        }),
        (n, _) => Err(ParseError::BadArity(n)),
    }
}

/// Parse a whole program, skipping blank lines and `#` comments.
pub fn parse_program(name: &str, text: &str) -> Result<Program, ParseError> {
    let mut p = Program::new(name);
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        p.push(parse_instr(line)?);
    }
    Ok(p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::program;
    use crate::dram::command::RowId::*;

    #[test]
    fn roundtrip_all_table2_programs() {
        let progs = [
            program::copy(Data(0), Data(1)),
            program::not(Data(0), Data(1)),
            program::maj3(Data(0), Data(1), Data(2), Data(3)),
            program::xnor2(Data(0), Data(1), Data(2)),
            program::xor2(Data(0), Data(1), Data(2)),
            program::full_adder(Data(0), Data(1), Data(2), Data(3), Data(4)),
            program::full_subtractor(Data(0), Data(1), Data(2), Data(3), Data(4)),
        ];
        for p in progs {
            let text = format_program(&p);
            let back = parse_program(&p.name, &text).unwrap();
            assert_eq!(back, p, "roundtrip failed for {}", p.name);
        }
    }

    #[test]
    fn parse_errors() {
        assert!(matches!(
            parse_instr("nonsense"),
            Err(ParseError::BadSyntax(_))
        ));
        assert!(matches!(
            parse_instr("AAP(d0, q9)"),
            Err(ParseError::BadRow(_))
        ));
        assert!(matches!(
            parse_instr("AAP(d0, d1, d2, d3, d4)"),
            Err(ParseError::BadArity(5))
        ));
    }

    #[test]
    fn type2_vs_type3_disambiguation() {
        let t2 = parse_instr("AAP2(d0, x1, x2)").unwrap();
        assert!(matches!(t2, AapInstr::Aap2 { .. }));
        let t3 = parse_instr("AAP(x1, x2, d0)").unwrap();
        assert!(matches!(t3, AapInstr::Aap3 { .. }));
    }

    #[test]
    fn comments_and_blank_lines_skipped() {
        let p = parse_program("t", "# hello\n\nAAP(d0, x1)\n").unwrap();
        assert_eq!(p.aap_count(), 1);
    }
}
