//! Table 2 micro-programs: the basic functions supported by DRIM, expressed
//! as AAP sequences, plus the TRA-composed AND/OR family ("other operations
//! such AND2/NAND2 and OR2/NOR2 in DRIM can be built on top of TRA").
//!
//! Control rows: DRIM (like Ambit) reserves two data rows per sub-array
//! preset to all-zeros / all-ones for TRA-composed AND2/OR2 and for
//! carry-in initialization. We use the top of the data-row space.

use crate::dram::command::RowId::{self, *};

use super::{AapInstr, Program};

/// Reserved all-zeros preset row (initialized once by the controller at
/// power-up, refreshed by RowClone from itself like any other row).
pub const CTRL_ZEROS: RowId = Data(499);
/// Reserved all-ones preset row (TRA-composed OR2 and carry/borrow init).
pub const CTRL_ONES: RowId = Data(498);
/// First data row usable by the allocator.
pub const FIRST_FREE_DATA_ROW: u16 = 0;
/// Last data row usable by the allocator (exclusive).
pub const LAST_FREE_DATA_ROW: u16 = 498;

/// `Dr ← Di` — Table 2 "copy": 1 AAP.
pub fn copy(di: RowId, dr: RowId) -> Program {
    let mut p = Program::new("copy");
    p.push(AapInstr::Aap1 { src: di, des: dr });
    p
}

/// `Dr ← !Di` — Table 2 "NOT": 2 AAPs through DCC cell A.
pub fn not(di: RowId, dr: RowId) -> Program {
    let mut p = Program::new("not");
    // writing through dcc2 (cell A complement WL) stores !Di in cell A
    p.push(AapInstr::Aap1 { src: di, des: Dcc(2) });
    // reading through dcc1 (normal WL) presents cell A = !Di on BL
    p.push(AapInstr::Aap1 { src: Dcc(1), des: dr });
    p
}

/// `Dr ← MAJ3(Di, Dj, Dk)` — Table 2 "MAJ": 4 AAPs (3 copies + TRA).
pub fn maj3(di: RowId, dj: RowId, dk: RowId, dr: RowId) -> Program {
    let mut p = Program::new("maj3");
    p.push(AapInstr::Aap1 { src: di, des: X(1) });
    p.push(AapInstr::Aap1 { src: dj, des: X(2) });
    p.push(AapInstr::Aap1 { src: dk, des: X(3) });
    p.push(AapInstr::Aap4 {
        src: [X(1), X(2), X(3)],
        des: dr,
    });
    p
}

/// `Dr ← MIN3(Di, Dj, Dk)` — complement of MAJ3 via DCC: 5 AAPs.
pub fn min3(di: RowId, dj: RowId, dk: RowId, dr: RowId) -> Program {
    let mut p = Program::new("min3");
    p.push(AapInstr::Aap1 { src: di, des: X(1) });
    p.push(AapInstr::Aap1 { src: dj, des: X(2) });
    p.push(AapInstr::Aap1 { src: dk, des: X(3) });
    p.push(AapInstr::Aap4 {
        src: [X(1), X(2), X(3)],
        des: Dcc(2),
    });
    p.push(AapInstr::Aap1 { src: Dcc(1), des: dr });
    p
}

/// `Dr ← Di ⊙ Dj` — Table 2 "XNOR2": 3 AAPs, the paper's headline op.
pub fn xnor2(di: RowId, dj: RowId, dr: RowId) -> Program {
    let mut p = Program::new("xnor2");
    p.push(AapInstr::Aap1 { src: di, des: X(1) });
    p.push(AapInstr::Aap1 { src: dj, des: X(2) });
    p.push(AapInstr::Aap3 {
        src: [X(1), X(2)],
        des: dr,
    });
    p
}

/// `Dr ← Di ⊕ Dj` — XOR2 = XNOR2 routed through a DCC complement
/// word-line (Table 2 footnote): 4 AAPs.
pub fn xor2(di: RowId, dj: RowId, dr: RowId) -> Program {
    let mut p = Program::new("xor2");
    p.push(AapInstr::Aap1 { src: di, des: X(1) });
    p.push(AapInstr::Aap1 { src: dj, des: X(2) });
    // BL carries XNOR; storing via dcc2 leaves cell A = XOR
    p.push(AapInstr::Aap3 {
        src: [X(1), X(2)],
        des: Dcc(2),
    });
    p.push(AapInstr::Aap1 { src: Dcc(1), des: dr });
    p
}

/// `Dr ← Di AND Dj` — TRA with the zeros control row: 4 AAPs ("averagely
/// 360ns", paper §2.2). MAJ3(a, b, 0) = a·b.
pub fn and2(di: RowId, dj: RowId, dr: RowId) -> Program {
    let mut p = Program::new("and2");
    p.push(AapInstr::Aap1 { src: di, des: X(1) });
    p.push(AapInstr::Aap1 { src: dj, des: X(2) });
    p.push(AapInstr::Aap1 { src: CTRL_ZEROS, des: X(3) });
    p.push(AapInstr::Aap4 {
        src: [X(1), X(2), X(3)],
        des: dr,
    });
    p
}

/// `Dr ← Di OR Dj` — TRA with the ones control row: MAJ3(a, b, 1) = a+b.
pub fn or2(di: RowId, dj: RowId, dr: RowId) -> Program {
    let mut p = Program::new("or2");
    p.push(AapInstr::Aap1 { src: di, des: X(1) });
    p.push(AapInstr::Aap1 { src: dj, des: X(2) });
    p.push(AapInstr::Aap1 { src: CTRL_ONES, des: X(3) });
    p.push(AapInstr::Aap4 {
        src: [X(1), X(2), X(3)],
        des: dr,
    });
    p
}

/// `Dr ← !(Di AND Dj)` — AND2 into DCC, read complement: 5 AAPs.
pub fn nand2(di: RowId, dj: RowId, dr: RowId) -> Program {
    let mut p = and2(di, dj, Dcc(2));
    p.name = "nand2".into();
    p.push(AapInstr::Aap1 { src: Dcc(1), des: dr });
    p
}

/// `Dr ← !(Di OR Dj)` — OR2 into DCC, read complement: 5 AAPs.
pub fn nor2(di: RowId, dj: RowId, dr: RowId) -> Program {
    let mut p = or2(di, dj, Dcc(2));
    p.name = "nor2".into();
    p.push(AapInstr::Aap1 { src: Dcc(1), des: dr });
    p
}

/// One full-adder bit-slice — Table 2 "Add/Sub", 7 AAPs:
///
/// `Sum ← Di ⊕ Dj ⊕ Dk` (two back-to-back DRA XOR2s),
/// `Cout ← MAJ3(Di, Dj, Dk)` (one TRA).
///
/// Note on the final TRA: the paper's table prints `AAP(x1, x2, x3, Cout)`,
/// but x2 and x4 are consumed (destructively) by the first DRA and x6 by
/// the second — that is exactly why each operand is double-copied by the
/// AAP-type-2s. The intact copies are x1, x3, x5, which is what we (and
/// any working implementation) must feed the TRA.
pub fn full_adder(
    di: RowId,
    dj: RowId,
    dk: RowId,
    sum: RowId,
    cout: RowId,
) -> Program {
    let mut p = Program::new("add");
    p.push(AapInstr::Aap2 { src: di, des: [X(1), X(2)] });
    p.push(AapInstr::Aap2 { src: dj, des: [X(3), X(4)] });
    p.push(AapInstr::Aap2 { src: dk, des: [X(5), X(6)] });
    // DRA(x2, x4) → BL = XNOR(a,b); store via dcc2 → cell A = a⊕b
    p.push(AapInstr::Aap3 {
        src: [X(2), X(4)],
        des: Dcc(2),
    });
    // DRA(x6, dcc1) → BL = XNOR(c, a⊕b); store via dcc4 → cell B = Sum
    p.push(AapInstr::Aap3 {
        src: [X(6), Dcc(1)],
        des: Dcc(4),
    });
    p.push(AapInstr::Aap1 { src: Dcc(3), des: sum });
    // TRA over the untouched copies → carry-out
    p.push(AapInstr::Aap4 {
        src: [X(1), X(3), X(5)],
        des: cout,
    });
    p
}

/// One full-subtractor bit-slice: `a - b = a + !b (+1 via carry-in)`.
/// 8 AAPs — the Dj copy is replaced by a NOT-copy through DCC cell A.
pub fn full_subtractor(
    di: RowId,
    dj: RowId,
    bk: RowId,
    diff: RowId,
    bout: RowId,
) -> Program {
    let mut p = Program::new("sub");
    p.push(AapInstr::Aap2 { src: di, des: [X(1), X(2)] });
    // !Dj via DCC cell A, then double-copy it
    p.push(AapInstr::Aap1 { src: dj, des: Dcc(2) });
    p.push(AapInstr::Aap2 {
        src: Dcc(1),
        des: [X(3), X(4)],
    });
    p.push(AapInstr::Aap2 { src: bk, des: [X(5), X(6)] });
    p.push(AapInstr::Aap3 {
        src: [X(2), X(4)],
        des: Dcc(2),
    });
    p.push(AapInstr::Aap3 {
        src: [X(6), Dcc(1)],
        des: Dcc(4),
    });
    p.push(AapInstr::Aap1 { src: Dcc(3), des: diff });
    p.push(AapInstr::Aap4 {
        src: [X(1), X(3), X(5)],
        des: bout,
    });
    p
}

/// The op vocabulary exposed by the coordinator / CLI.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, PartialOrd, Ord)]
pub enum BulkOp {
    /// RowClone-style in-array copy (1 AAP).
    Copy,
    /// Bit-wise complement via DCC (2 AAPs).
    Not,
    /// The headline dual-row-activation XNOR (3 AAPs).
    Xnor2,
    /// XNOR through a DCC complement word-line (4 AAPs).
    Xor2,
    /// TRA with the zeros control row: MAJ3(a, b, 0) (4 AAPs).
    And2,
    /// TRA with the ones control row: MAJ3(a, b, 1) (4 AAPs).
    Or2,
    /// AND2 read back complemented through DCC (5 AAPs).
    Nand2,
    /// OR2 read back complemented through DCC (5 AAPs).
    Nor2,
    /// Native triple-row-activation majority (4 AAPs).
    Maj3,
    /// Complemented majority (5 AAPs).
    Min3,
    /// Element-wise 32-bit addition, bit-serial over planes (7 AAPs/slice).
    Add,
    /// Element-wise 32-bit subtraction (8 AAPs/slice).
    Sub,
}

impl BulkOp {
    /// Number of operands the op consumes.
    pub fn arity(self) -> usize {
        match self {
            BulkOp::Copy | BulkOp::Not => 1,
            BulkOp::Maj3 | BulkOp::Min3 | BulkOp::Add | BulkOp::Sub => 3,
            _ => 2,
        }
    }

    /// Parse a (case-insensitive) op name as the CLI accepts it.
    pub fn parse(s: &str) -> Option<BulkOp> {
        Some(match s.to_ascii_lowercase().as_str() {
            "copy" => BulkOp::Copy,
            "not" => BulkOp::Not,
            "xnor" | "xnor2" => BulkOp::Xnor2,
            "xor" | "xor2" => BulkOp::Xor2,
            "and" | "and2" => BulkOp::And2,
            "or" | "or2" => BulkOp::Or2,
            "nand" | "nand2" => BulkOp::Nand2,
            "nor" | "nor2" => BulkOp::Nor2,
            "maj" | "maj3" => BulkOp::Maj3,
            "min" | "min3" => BulkOp::Min3,
            "add" => BulkOp::Add,
            "sub" => BulkOp::Sub,
            _ => return None,
        })
    }

    /// Canonical lowercase name (round-trips through [`BulkOp::parse`]).
    pub fn name(self) -> &'static str {
        match self {
            BulkOp::Copy => "copy",
            BulkOp::Not => "not",
            BulkOp::Xnor2 => "xnor2",
            BulkOp::Xor2 => "xor2",
            BulkOp::And2 => "and2",
            BulkOp::Or2 => "or2",
            BulkOp::Nand2 => "nand2",
            BulkOp::Nor2 => "nor2",
            BulkOp::Maj3 => "maj3",
            BulkOp::Min3 => "min3",
            BulkOp::Add => "add",
            BulkOp::Sub => "sub",
        }
    }

    /// Build the micro-program for this op over generic operand rows.
    /// `add`/`sub` return the *bit-slice* program (the controller iterates
    /// planes); `srcs[2]` is then the carry/borrow-in row and `dests[1]`
    /// the carry/borrow-out row.
    pub fn program(self, srcs: &[RowId], dests: &[RowId]) -> Program {
        match self {
            BulkOp::Copy => copy(srcs[0], dests[0]),
            BulkOp::Not => not(srcs[0], dests[0]),
            BulkOp::Xnor2 => xnor2(srcs[0], srcs[1], dests[0]),
            BulkOp::Xor2 => xor2(srcs[0], srcs[1], dests[0]),
            BulkOp::And2 => and2(srcs[0], srcs[1], dests[0]),
            BulkOp::Or2 => or2(srcs[0], srcs[1], dests[0]),
            BulkOp::Nand2 => nand2(srcs[0], srcs[1], dests[0]),
            BulkOp::Nor2 => nor2(srcs[0], srcs[1], dests[0]),
            BulkOp::Maj3 => maj3(srcs[0], srcs[1], srcs[2], dests[0]),
            BulkOp::Min3 => min3(srcs[0], srcs[1], srcs[2], dests[0]),
            BulkOp::Add => full_adder(srcs[0], srcs[1], srcs[2], dests[0], dests[1]),
            BulkOp::Sub => {
                full_subtractor(srcs[0], srcs[1], srcs[2], dests[0], dests[1])
            }
        }
    }

    /// Every op, in Table 2 order (exhaustive-test convenience).
    pub const ALL: [BulkOp; 12] = [
        BulkOp::Copy,
        BulkOp::Not,
        BulkOp::Xnor2,
        BulkOp::Xor2,
        BulkOp::And2,
        BulkOp::Or2,
        BulkOp::Nand2,
        BulkOp::Nor2,
        BulkOp::Maj3,
        BulkOp::Min3,
        BulkOp::Add,
        BulkOp::Sub,
    ];
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_aap_counts() {
        // Table 2, column "Command Sequence": copy=1, NOT=2, MAJ=4,
        // XNOR2=3, Add=7 AAPs.
        assert_eq!(copy(Data(0), Data(1)).aap_count(), 1);
        assert_eq!(not(Data(0), Data(1)).aap_count(), 2);
        assert_eq!(maj3(Data(0), Data(1), Data(2), Data(3)).aap_count(), 4);
        assert_eq!(xnor2(Data(0), Data(1), Data(2)).aap_count(), 3);
        assert_eq!(
            full_adder(Data(0), Data(1), Data(2), Data(3), Data(4)).aap_count(),
            7
        );
    }

    #[test]
    fn and2_is_four_aaps_360ns() {
        // paper §2.2: "TRA method needs averagely 360ns" for AND2/OR2
        let t = crate::dram::timing::TimingParams::default();
        assert_eq!(and2(Data(0), Data(1), Data(2)).duration_ns(&t), 360.0);
    }

    #[test]
    fn all_programs_validate() {
        use crate::dram::command::AapKind;
        use crate::subarray::decoder::validate_aap;
        for op in BulkOp::ALL {
            let srcs = [Data(0), Data(1), Data(2)];
            let dests = [Data(3), Data(4)];
            let p = op.program(&srcs[..op.arity()], &dests);
            assert!(!p.instrs.is_empty());
            for i in &p.instrs {
                let k: AapKind = i.kind();
                validate_aap(k, &i.sources(), &i.dests());
            }
        }
    }

    #[test]
    fn bulkop_parse_names() {
        for op in BulkOp::ALL {
            assert_eq!(BulkOp::parse(op.name()), Some(op));
        }
        assert_eq!(BulkOp::parse("xnor"), Some(BulkOp::Xnor2));
        assert_eq!(BulkOp::parse("nonsense"), None);
    }

    #[test]
    fn xnor_uses_dra_not_tra() {
        let p = xnor2(Data(0), Data(1), Data(2));
        let kinds: Vec<_> = p.instrs.iter().map(|i| i.kind()).collect();
        assert!(kinds.contains(&crate::dram::command::AapKind::Dra));
        assert!(!kinds.contains(&crate::dram::command::AapKind::Tra));
    }
}
