//! Bit-serial vector arithmetic on 32-bit elements (paper §3.1 In-Memory
//! Adder) plus a fused multiply-by-small-constant built from shifts+adds —
//! demonstrating composition of the service's add primitive.

use crate::coordinator::{BulkRequest, DrimService, Payload};

/// `a + b` element-wise inside DRIM.
pub fn add(service: &DrimService, a: &[u32], b: &[u32]) -> Vec<u32> {
    let resp = service.run(BulkRequest::add32(a.to_vec(), b.to_vec()));
    match resp.result {
        Payload::U32(v) => v,
        _ => unreachable!(),
    }
}

/// `a - b` element-wise inside DRIM.
pub fn sub(service: &DrimService, a: &[u32], b: &[u32]) -> Vec<u32> {
    let resp = service.run(BulkRequest::sub32(a.to_vec(), b.to_vec()));
    match resp.result {
        Payload::U32(v) => v,
        _ => unreachable!(),
    }
}

/// `a * k` for small constants via shift-and-add (each shift is free —
/// it is a host-side relabeling of bit-planes; adds run in memory).
pub fn mul_const(service: &DrimService, a: &[u32], k: u32) -> Vec<u32> {
    let mut acc = vec![0u32; a.len()];
    let mut shifted: Vec<u32> = a.to_vec();
    let mut kk = k;
    while kk != 0 {
        if kk & 1 == 1 {
            acc = add(service, &acc, &shifted);
        }
        shifted = shifted.iter().map(|&x| x << 1).collect();
        kk >>= 1;
    }
    acc
}

/// Sum-reduce a vector by repeated halving (log₂ n in-memory adds).
pub fn reduce_sum(service: &DrimService, v: &[u32]) -> u32 {
    let mut cur = v.to_vec();
    while cur.len() > 1 {
        let half = cur.len().div_ceil(2);
        let (lo, hi) = cur.split_at(half);
        let mut hi = hi.to_vec();
        hi.resize(half, 0);
        cur = add(service, &lo.to_vec(), &hi);
    }
    cur.first().copied().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::router::ServiceConfig;
    use crate::coordinator::DrimService;
    use crate::util::rng::Rng;

    fn service() -> DrimService {
        DrimService::new(ServiceConfig::tiny())
    }

    #[test]
    fn add_sub_roundtrip() {
        let s = service();
        let mut rng = Rng::new(5);
        let a: Vec<u32> = (0..300).map(|_| rng.next_u64() as u32).collect();
        let b: Vec<u32> = (0..300).map(|_| rng.next_u64() as u32).collect();
        let sum = add(&s, &a, &b);
        let back = sub(&s, &sum, &b);
        assert_eq!(back, a);
    }

    #[test]
    fn mul_const_matches_host() {
        let s = service();
        let a: Vec<u32> = (0..64).map(|i| i * 977).collect();
        for k in [0u32, 1, 3, 10] {
            let got = mul_const(&s, &a, k);
            let want: Vec<u32> = a.iter().map(|&x| x.wrapping_mul(k)).collect();
            assert_eq!(got, want, "k={k}");
        }
    }

    #[test]
    fn reduce_sum_matches_host() {
        let s = service();
        let v: Vec<u32> = (1..=100).collect();
        assert_eq!(reduce_sum(&s, &v), 5050);
        assert_eq!(reduce_sum(&s, &[]), 0);
        assert_eq!(reduce_sum(&s, &[7]), 7);
    }
}
