//! DNA short-read matching via in-memory XNOR + popcount.
//!
//! Encoding: 2 bits per base (A=00, C=01, G=10, T=11). A read matches a
//! reference window when `XNOR(read, window)` is all-ones; Hamming
//! similarity = popcount of the XNOR (paper §1: "applications such as DNA
//! alignment" are XNOR-bound). The XNOR runs in DRIM through the service;
//! the final popcount/threshold is the cheap host-side reduction, as in the
//! paper's usage model.

use crate::coordinator::{BulkRequest, DrimService, Payload};
use crate::isa::program::BulkOp;
use crate::util::bitrow::BitRow;
use crate::util::rng::Rng;

/// The four bases, in 2-bit encoding order (A=00 … T=11).
pub const BASES: [char; 4] = ['A', 'C', 'G', 'T'];

/// 2-bit-encode a DNA string.
pub fn encode(seq: &str) -> BitRow {
    let mut row = BitRow::zeros(seq.len() * 2);
    for (i, ch) in seq.chars().enumerate() {
        let code = match ch {
            'A' | 'a' => 0u8,
            'C' | 'c' => 1,
            'G' | 'g' => 2,
            'T' | 't' => 3,
            _ => panic!("not a base: {ch}"),
        };
        row.set(2 * i, code & 1 == 1);
        row.set(2 * i + 1, code & 2 == 2);
    }
    row
}

/// Random genome of `n` bases.
pub fn random_genome(n: usize, rng: &mut Rng) -> String {
    (0..n).map(|_| BASES[rng.below(4) as usize]).collect()
}

/// One alignment hit.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Hit {
    /// genome offset (in bases) of the matching window
    pub position: usize,
    /// matching bases (read length = max)
    pub score: usize,
}

/// Align `read` against every window of `genome`, batched through DRIM:
/// all windows are concatenated into one bulk XNOR2 request (one row chunk
/// per window batch), then scored by popcount. Returns hits with at least
/// `min_matches` matching bases.
pub fn align(
    service: &DrimService,
    genome: &str,
    read: &str,
    min_matches: usize,
) -> Vec<Hit> {
    assert!(read.len() <= genome.len());
    let w = read.len() * 2;
    let n_windows = genome.len() - read.len() + 1;
    let read_bits = encode(read);
    let genome_bits = encode(genome);

    // big batched payload: window i occupies bits [i*w, (i+1)*w)
    let mut windows = BitRow::zeros(n_windows * w);
    let mut reads = BitRow::zeros(n_windows * w);
    for i in 0..n_windows {
        for b in 0..w {
            windows.set(i * w + b, genome_bits.get(i * 2 + b));
            reads.set(i * w + b, read_bits.get(b));
        }
    }
    let resp = service.run(BulkRequest::bitwise(BulkOp::Xnor2, vec![reads, windows]));
    let xnor = match resp.result {
        Payload::Bits(b) => b,
        _ => unreachable!(),
    };

    let mut hits = Vec::new();
    for i in 0..n_windows {
        // a base matches iff *both* of its bits match
        let mut score = 0;
        for base in 0..read.len() {
            if xnor.get(i * w + 2 * base) && xnor.get(i * w + 2 * base + 1) {
                score += 1;
            }
        }
        if score >= min_matches {
            hits.push(Hit { position: i, score });
        }
    }
    hits
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::router::ServiceConfig;

    fn service() -> DrimService {
        DrimService::new(ServiceConfig::tiny())
    }

    #[test]
    fn encode_roundtrip_bits() {
        let r = encode("ACGT");
        // A=00 C=01(bit0) G=10(bit1) T=11
        assert!(!r.get(0) && !r.get(1));
        assert!(r.get(2) && !r.get(3));
        assert!(!r.get(4) && r.get(5));
        assert!(r.get(6) && r.get(7));
    }

    #[test]
    fn exact_match_found() {
        let s = service();
        let genome = "ACGTACGGTTACGATCGA";
        let read = "GGTTAC";
        let hits = align(&s, genome, read, read.len());
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].position, genome.find(read).unwrap());
        assert_eq!(hits[0].score, read.len());
    }

    #[test]
    fn approximate_match_scores() {
        let s = service();
        let genome = "AAAAAAAAAA";
        let read = "AAAT"; // 3 of 4 bases match everywhere
        let hits = align(&s, genome, read, 3);
        assert_eq!(hits.len(), genome.len() - read.len() + 1);
        assert!(hits.iter().all(|h| h.score == 3));
        assert!(align(&s, genome, read, 4).is_empty());
    }

    #[test]
    fn random_genome_planted_read() {
        let mut rng = Rng::new(42);
        let s = service();
        let mut genome = random_genome(300, &mut rng);
        let read = "TTGACGTAGCAT";
        genome.replace_range(100..100 + read.len(), read);
        let hits = align(&s, &genome, read, read.len());
        assert!(hits.iter().any(|h| h.position == 100));
    }
}
