//! In-memory XOR stream cipher (one-time-pad style) — the paper's "data
//! encryption" motivating workload.
//!
//! Keystream generation stays on the host (it is not the bulk-bandwidth
//! bottleneck); the bulk XOR of payload × keystream runs inside DRIM.

use crate::coordinator::{BulkRequest, DrimService, Payload};
use crate::isa::program::BulkOp;
use crate::util::bitrow::BitRow;
use crate::util::rng::Rng;

/// Expand a 64-bit key into a keystream of `bits` (xoshiro-based; a real
/// deployment would use a stream cipher — the in-memory data path is
/// identical).
pub fn keystream(key: u64, bits: usize) -> BitRow {
    BitRow::random(bits, &mut Rng::new(key))
}

/// Encrypt (= decrypt) `data` under `key` inside DRIM.
pub fn apply(service: &DrimService, data: &BitRow, key: u64) -> BitRow {
    let ks = keystream(key, data.len());
    let resp = service.run(BulkRequest::bitwise(
        BulkOp::Xor2,
        vec![data.clone(), ks],
    ));
    match resp.result {
        Payload::Bits(b) => b,
        _ => unreachable!(),
    }
}

/// Bytes → BitRow and back, for byte-oriented callers.
pub fn bits_from_bytes(bytes: &[u8]) -> BitRow {
    let mut r = BitRow::zeros(bytes.len() * 8);
    for (i, &by) in bytes.iter().enumerate() {
        for b in 0..8 {
            r.set(i * 8 + b, (by >> b) & 1 == 1);
        }
    }
    r
}

/// BitRow → bytes, inverse of [`bits_from_bytes`].
pub fn bytes_from_bits(row: &BitRow) -> Vec<u8> {
    let n = row.len() / 8;
    (0..n)
        .map(|i| {
            (0..8).fold(0u8, |acc, b| acc | ((row.get(i * 8 + b) as u8) << b))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::router::ServiceConfig;

    fn service() -> DrimService {
        DrimService::new(ServiceConfig::tiny())
    }

    #[test]
    fn roundtrip_restores_plaintext() {
        let s = service();
        let msg = bits_from_bytes(b"in-memory one-time pad, row-parallel");
        let ct = apply(&s, &msg, 0xBEEF);
        assert_ne!(ct, msg);
        let pt = apply(&s, &ct, 0xBEEF);
        assert_eq!(pt, msg);
    }

    #[test]
    fn wrong_key_fails_to_decrypt() {
        let s = service();
        let msg = bits_from_bytes(b"secret");
        let ct = apply(&s, &msg, 1);
        let pt = apply(&s, &ct, 2);
        assert_ne!(pt, msg);
    }

    #[test]
    fn bytes_roundtrip() {
        let data = vec![0u8, 1, 2, 254, 255, 0x5A];
        assert_eq!(bytes_from_bits(&bits_from_bytes(&data)), data);
    }

    #[test]
    fn ciphertext_has_no_trivial_structure() {
        let s = service();
        let msg = BitRow::zeros(4096); // all-zero plaintext exposes keystream
        let ct = apply(&s, &msg, 7);
        let ones = ct.popcount();
        assert!((1200..2900).contains(&ones), "keystream bias: {ones}");
    }
}
