//! Library-level applications — the workloads the paper's introduction
//! motivates for X(N)OR-heavy PIM: DNA sequence alignment and data
//! encryption, plus bit-serial vector arithmetic.
//!
//! Each app is written against the public `coordinator::DrimService` API
//! only (no reaching into the array), exactly as a downstream user would.
#![warn(missing_docs)]

pub mod bnn;
pub mod cipher;
pub mod dna;
pub mod vecadd;
