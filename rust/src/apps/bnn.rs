//! Binarized-neural-network inference on DRIM — the DNN acceleration
//! use-case the paper inherits from DRISA [3] / Dracc [21]: a binary dense
//! layer is exactly `popcount(XNOR(weights, activations))`, i.e. the
//! paper's headline bulk operation.
//!
//! All XNOR compute runs in-memory through the service (one batched
//! request per layer: every neuron's weight row against the broadcast
//! activation vector); the popcount-and-threshold binarization is the
//! cheap host-side reduction, as in the DRISA usage model.

use crate::coordinator::{BulkRequest, DrimService, Payload};
use crate::isa::program::BulkOp;
use crate::util::bitrow::BitRow;
use crate::util::rng::Rng;

/// One binary dense layer: `out` neurons × `inp` binary inputs.
#[derive(Clone, Debug)]
pub struct BinaryLayer {
    /// input width in bits
    pub inp: usize,
    /// number of output neurons
    pub out: usize,
    /// weight matrix, one BitRow of `inp` bits per output neuron
    pub weights: Vec<BitRow>,
    /// activation threshold (neuron fires when matches ≥ threshold);
    /// the canonical BNN sign() corresponds to `inp / 2`
    pub threshold: usize,
}

impl BinaryLayer {
    /// Random weights, canonical `inp / 2` threshold.
    pub fn random(inp: usize, out: usize, rng: &mut Rng) -> Self {
        BinaryLayer {
            inp,
            out,
            weights: (0..out).map(|_| BitRow::random(inp, rng)).collect(),
            threshold: inp / 2,
        }
    }

    /// Forward pass for one binary input vector.
    pub fn forward(&self, service: &DrimService, x: &BitRow) -> BitRow {
        assert_eq!(x.len(), self.inp);
        // batch all neurons into one request: weight rows concatenated vs
        // the activation vector broadcast per neuron
        let mut w_cat = BitRow::zeros(self.out * self.inp);
        let mut x_cat = BitRow::zeros(self.out * self.inp);
        for (j, w) in self.weights.iter().enumerate() {
            w_cat.copy_bits_from(w, 0, j * self.inp, self.inp);
            x_cat.copy_bits_from(x, 0, j * self.inp, self.inp);
        }
        let resp = service.run(BulkRequest::bitwise(BulkOp::Xnor2, vec![w_cat, x_cat]));
        let xnor = match resp.result {
            Payload::Bits(b) => b,
            _ => unreachable!(),
        };
        // binarize: popcount per neuron segment against the threshold
        let mut y = BitRow::zeros(self.out);
        let mut seg = BitRow::zeros(self.inp);
        for j in 0..self.out {
            seg.copy_bits_from(&xnor, j * self.inp, 0, self.inp);
            y.set(j, seg.popcount() >= self.threshold);
        }
        y
    }

    /// Host reference (for tests).
    pub fn forward_host(&self, x: &BitRow) -> BitRow {
        let mut y = BitRow::zeros(self.out);
        for (j, w) in self.weights.iter().enumerate() {
            let matches = (0..self.inp).filter(|&i| w.get(i) == x.get(i)).count();
            y.set(j, matches >= self.threshold);
        }
        y
    }
}

/// A stack of binary layers (a BNN MLP).
#[derive(Clone, Debug)]
pub struct BinaryMlp {
    /// the dense layers, input-first
    pub layers: Vec<BinaryLayer>,
}

impl BinaryMlp {
    /// Random MLP with the given layer widths (`dims[0]` = input bits).
    pub fn random(dims: &[usize], rng: &mut Rng) -> Self {
        assert!(dims.len() >= 2);
        BinaryMlp {
            layers: dims
                .windows(2)
                .map(|w| BinaryLayer::random(w[0], w[1], rng))
                .collect(),
        }
    }

    /// Forward pass through every layer, XNORs in-memory.
    pub fn forward(&self, service: &DrimService, x: &BitRow) -> BitRow {
        let mut a = x.clone();
        for l in &self.layers {
            a = l.forward(service, &a);
        }
        a
    }

    /// Host reference forward pass (for tests).
    pub fn forward_host(&self, x: &BitRow) -> BitRow {
        let mut a = x.clone();
        for l in &self.layers {
            a = l.forward_host(&a);
        }
        a
    }

    /// Classify: index of the first set output bit, or argmax-like pick.
    pub fn classify(&self, service: &DrimService, x: &BitRow) -> usize {
        let y = self.forward(service, x);
        (0..y.len()).find(|&i| y.get(i)).unwrap_or(0)
    }

    /// Total XNOR bit-operations per forward pass (for throughput math).
    pub fn ops_per_inference(&self) -> usize {
        self.layers.iter().map(|l| l.inp * l.out).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::router::ServiceConfig;

    fn service() -> DrimService {
        DrimService::new(ServiceConfig::tiny())
    }

    #[test]
    fn layer_matches_host_reference() {
        let mut rng = Rng::new(1);
        let s = service();
        let l = BinaryLayer::random(64, 16, &mut rng);
        for _ in 0..5 {
            let x = BitRow::random(64, &mut rng);
            assert_eq!(l.forward(&s, &x), l.forward_host(&x));
        }
    }

    #[test]
    fn mlp_matches_host_reference() {
        let mut rng = Rng::new(2);
        let s = service();
        let net = BinaryMlp::random(&[32, 24, 8], &mut rng);
        for _ in 0..3 {
            let x = BitRow::random(32, &mut rng);
            assert_eq!(net.forward(&s, &x), net.forward_host(&x));
        }
    }

    #[test]
    fn perfect_match_neuron_fires() {
        let mut rng = Rng::new(3);
        let s = service();
        let mut l = BinaryLayer::random(40, 4, &mut rng);
        l.threshold = 40; // only exact weight match fires
        let x = l.weights[2].clone();
        let y = l.forward(&s, &x);
        assert!(y.get(2));
        // a far-away pattern must not fire neuron 2
        let mut far = x.clone();
        for i in 0..30 {
            let v = far.get(i);
            far.set(i, !v);
        }
        assert!(!l.forward(&s, &far).get(2));
    }

    #[test]
    fn ops_accounting() {
        let mut rng = Rng::new(4);
        let net = BinaryMlp::random(&[128, 64, 10], &mut rng);
        assert_eq!(net.ops_per_inference(), 128 * 64 + 64 * 10);
    }
}
