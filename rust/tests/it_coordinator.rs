//! Serving-layer integration: multi-chunk payloads, concurrency, batching
//! policies, and metrics consistency.

mod common;

use common::host_op;
use drim::coordinator::{
    BatchPolicy, BulkRequest, DrimService, Payload, Router, ServiceConfig,
};
use drim::isa::program::BulkOp;
use drim::util::bitrow::BitRow;
use drim::util::rng::Rng;

fn tiny_service(policy: BatchPolicy) -> DrimService {
    DrimService::new(ServiceConfig {
        policy,
        ..ServiceConfig::tiny()
    })
}

#[test]
fn every_bitwise_op_through_the_service() {
    let s = tiny_service(BatchPolicy::Coalesce);
    let mut rng = Rng::new(1);
    for op in [
        BulkOp::Not,
        BulkOp::Xnor2,
        BulkOp::Xor2,
        BulkOp::And2,
        BulkOp::Or2,
        BulkOp::Nand2,
        BulkOp::Nor2,
        BulkOp::Maj3,
        BulkOp::Min3,
    ] {
        let bits = 777 + (op as usize) * 131; // odd sizes cross chunks
        let operands: Vec<BitRow> = (0..op.arity())
            .map(|_| BitRow::random(bits, &mut rng))
            .collect();
        let resp = s.run(BulkRequest::bitwise(op, operands.clone()));
        let got = match resp.result {
            Payload::Bits(b) => b,
            _ => panic!(),
        };
        let refs: Vec<&BitRow> = operands.iter().collect();
        assert_eq!(got, host_op(op, &refs), "op {}", op.name());
    }
}

#[test]
fn large_payload_many_chunks() {
    let s = tiny_service(BatchPolicy::Coalesce);
    let mut rng = Rng::new(2);
    let bits = 100_000; // ~391 chunks at 256 cols
    let a = BitRow::random(bits, &mut rng);
    let b = BitRow::random(bits, &mut rng);
    let resp = s.run(BulkRequest::bitwise(BulkOp::Xnor2, vec![a.clone(), b.clone()]));
    let got = match resp.result {
        Payload::Bits(r) => r,
        _ => panic!(),
    };
    assert_eq!(got, host_op(BulkOp::Xnor2, &[&a, &b]));
    let snap = s.metrics.snapshot();
    assert_eq!(snap.chunks as usize, bits.div_ceil(256));
    assert_eq!(snap.aaps, 3 * snap.chunks); // 3 AAPs per XNOR2 chunk
}

#[test]
fn add_and_sub_roundtrip_through_service() {
    let s = tiny_service(BatchPolicy::Coalesce);
    let mut rng = Rng::new(3);
    let n = 700;
    let a: Vec<u32> = (0..n).map(|_| rng.next_u64() as u32).collect();
    let b: Vec<u32> = (0..n).map(|_| rng.next_u64() as u32).collect();
    let sum = match s.run(BulkRequest::add32(a.clone(), b.clone())).result {
        Payload::U32(v) => v,
        _ => panic!(),
    };
    for i in 0..n {
        assert_eq!(sum[i], a[i].wrapping_add(b[i]));
    }
    let diff = match s.run(BulkRequest::sub32(sum.clone(), b.clone())).result {
        Payload::U32(v) => v,
        _ => panic!(),
    };
    assert_eq!(diff, a);
}

#[test]
fn interleaved_concurrent_requests_are_isolated() {
    let s = tiny_service(BatchPolicy::Coalesce);
    let mut rng = Rng::new(4);
    let mut inputs = Vec::new();
    let mut pending = Vec::new();
    for _ in 0..12 {
        let a = BitRow::random(2048, &mut rng);
        let b = BitRow::random(2048, &mut rng);
        pending.push(s.submit(BulkRequest::bitwise(
            BulkOp::Xor2,
            vec![a.clone(), b.clone()],
        )));
        inputs.push((a, b));
    }
    for (i, p) in pending.into_iter().enumerate() {
        let resp = p.recv().unwrap();
        let got = match resp.result {
            Payload::Bits(r) => r,
            _ => panic!(),
        };
        let (a, b) = &inputs[i];
        assert_eq!(got, host_op(BulkOp::Xor2, &[a, b]), "request {i}");
    }
}

#[test]
fn batching_policy_changes_sim_latency_not_results() {
    let mut rng = Rng::new(5);
    let a = BitRow::random(10_000, &mut rng);
    let b = BitRow::random(10_000, &mut rng);
    let mut results = Vec::new();
    let mut latencies = Vec::new();
    for pol in [BatchPolicy::Immediate, BatchPolicy::Coalesce] {
        let s = tiny_service(pol);
        let resp = s.run(BulkRequest::bitwise(
            BulkOp::Xnor2,
            vec![a.clone(), b.clone()],
        ));
        latencies.push(resp.sim_latency_ns);
        results.push(match resp.result {
            Payload::Bits(r) => r,
            _ => panic!(),
        });
    }
    assert_eq!(results[0], results[1]);
    // single request: immediate == coalesce latency
    assert!((latencies[0] - latencies[1]).abs() < 1e-9);
}

#[test]
fn router_wave_math_consistent_with_metrics() {
    let cfg = ServiceConfig::tiny();
    let router = Router::new(cfg.clone());
    let s = DrimService::new(cfg);
    let mut rng = Rng::new(6);
    let bits = 5_000;
    let a = BitRow::random(bits, &mut rng);
    let resp = s.run(BulkRequest::bitwise(BulkOp::Not, vec![a]));
    let chunks = router.shard(0, bits).len();
    let expect = router.sim_latency_ns(BulkOp::Not, &[chunks]);
    assert!((resp.sim_latency_ns - expect).abs() < 1e-9);
}

#[test]
fn empty_edge_one_bit_request() {
    let s = tiny_service(BatchPolicy::Coalesce);
    let mut a = BitRow::zeros(1);
    a.set(0, true);
    let resp = s.run(BulkRequest::bitwise(BulkOp::Not, vec![a]));
    match resp.result {
        Payload::Bits(r) => {
            assert_eq!(r.len(), 1);
            assert!(!r.get(0));
        }
        _ => panic!(),
    }
}
