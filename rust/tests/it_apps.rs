//! Application-level integration on the full-size geometry service.

use drim::apps::{cipher, dna, vecadd};
use drim::coordinator::{DrimService, ServiceConfig};
use drim::util::bitrow::BitRow;
use drim::util::rng::Rng;

fn service() -> DrimService {
    DrimService::new(ServiceConfig::default())
}

#[test]
fn dna_pipeline_on_synthetic_genome() {
    let mut rng = Rng::new(0xD7A);
    let s = service();
    let mut genome = dna::random_genome(2_000, &mut rng);
    let read = "ACGTTGCAGGTCAT";
    // plant the read three times
    for pos in [150usize, 900, 1700] {
        genome.replace_range(pos..pos + read.len(), read);
    }
    let hits = dna::align(&s, &genome, read, read.len());
    for pos in [150usize, 900, 1700] {
        assert!(
            hits.iter().any(|h| h.position == pos),
            "planted hit at {pos} not found"
        );
    }
    // approximate search finds at least as many
    let approx = dna::align(&s, &genome, read, read.len() - 2);
    assert!(approx.len() >= hits.len());
}

#[test]
fn cipher_large_payload() {
    let s = service();
    let mut rng = Rng::new(0xC1F);
    let msg = BitRow::random(300_000, &mut rng);
    let ct = cipher::apply(&s, &msg, 0x1234_5678);
    assert_ne!(ct, msg);
    assert_eq!(cipher::apply(&s, &ct, 0x1234_5678), msg);
}

#[test]
fn vecadd_composition() {
    let s = service();
    let a: Vec<u32> = (0..10_000u32).collect();
    let b: Vec<u32> = (0..10_000u32).map(|x| x * 2).collect();
    let sum = vecadd::add(&s, &a, &b);
    assert!(sum.iter().enumerate().all(|(i, &v)| v == 3 * i as u32));
    let five_a = vecadd::mul_const(&s, &a, 5);
    assert!(five_a.iter().enumerate().all(|(i, &v)| v == 5 * i as u32));
}

#[test]
fn service_metrics_reflect_app_usage() {
    let s = service();
    let a: Vec<u32> = (0..1000u32).collect();
    let _ = vecadd::add(&s, &a, &a);
    let snap = s.metrics.snapshot();
    assert!(snap.requests >= 1);
    assert!(snap.aaps > 0);
    assert!(snap.sim_ns > 0);
}
