//! Helpers shared by the integration-test binaries (`it_coordinator`,
//! `it_cluster`): the host-side reference model for bulk bit-wise ops and
//! payload accessors. One definition so every suite verifies against the
//! same reference.

use drim::coordinator::Payload;
use drim::isa::program::BulkOp;
use drim::util::bitrow::BitRow;

/// Host (non-DRIM) reference implementation of the bit-wise op vocabulary.
#[allow(dead_code)]
pub fn host_op(op: BulkOp, ops: &[&BitRow]) -> BitRow {
    let mut out = BitRow::zeros(ops[0].len());
    match op {
        BulkOp::Not => out.not_from(ops[0]),
        BulkOp::Xnor2 => out.apply2(ops[0], ops[1], |x, y| !(x ^ y)),
        BulkOp::Xor2 => out.apply2(ops[0], ops[1], |x, y| x ^ y),
        BulkOp::And2 => out.apply2(ops[0], ops[1], |x, y| x & y),
        BulkOp::Or2 => out.apply2(ops[0], ops[1], |x, y| x | y),
        BulkOp::Nand2 => out.apply2(ops[0], ops[1], |x, y| !(x & y)),
        BulkOp::Nor2 => out.apply2(ops[0], ops[1], |x, y| !(x | y)),
        BulkOp::Maj3 => out.apply3(ops[0], ops[1], ops[2], |x, y, z| {
            (x & y) | (x & z) | (y & z)
        }),
        BulkOp::Min3 => out.apply3(ops[0], ops[1], ops[2], |x, y, z| {
            !((x & y) | (x & z) | (y & z))
        }),
        _ => unreachable!("host_op covers only bit-wise ops"),
    }
    out
}

/// Unwrap a bit payload (panics with a clear message on add32 results).
#[allow(dead_code)]
pub fn bits_of(p: &Payload) -> &BitRow {
    match p {
        Payload::Bits(b) => b,
        _ => panic!("expected bit payload"),
    }
}
