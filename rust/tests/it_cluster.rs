//! Fleet-layer integration: multi-device scale-out over the coordinator.
//!
//! Covers the acceptance gates for the cluster subsystem:
//!   * fleet responses are bit-identical to the single-device serving path
//!   * simulated throughput scales ≥3× from 1 → 4 devices (pure sharding,
//!     stealing disabled so the quantity is deterministic)
//!   * under admission-control shedding no admitted request is ever lost
//!     and shedding actually fires
//!   * fleet metrics merge consistently with per-device counters

mod common;

use common::{bits_of, host_op};
use drim::cluster::{AdmissionConfig, ClusterConfig, DeviceId, DrimCluster};
use drim::coordinator::{BulkRequest, DrimService, ServiceConfig};
use drim::isa::program::BulkOp;
use drim::util::bitrow::BitRow;
use drim::util::rng::Rng;

/// Every response from a 4-device fleet matches both the host reference
/// and the single-device serving path on the same request.
#[test]
fn fleet_matches_single_device_path() {
    let cluster = DrimCluster::new(ClusterConfig::tiny(4));
    let single = DrimService::new(ServiceConfig::tiny());
    let mut rng = Rng::new(41);
    let mut inputs = Vec::new();
    let mut pending = Vec::new();
    for i in 0..24 {
        let op = [BulkOp::Xnor2, BulkOp::Xor2, BulkOp::Not, BulkOp::Maj3][i % 4];
        let bits = 700 + 137 * i; // crosses chunk boundaries at cols=256
        let ops: Vec<BitRow> = (0..op.arity())
            .map(|_| BitRow::random(bits, &mut rng))
            .collect();
        pending.push(
            cluster
                .try_submit(BulkRequest::bitwise(op, ops.clone()))
                .expect("default admission bound fits 24 requests"),
        );
        inputs.push((op, ops));
    }
    for (i, p) in pending.into_iter().enumerate() {
        let resp = p.recv().expect("fleet response");
        let (op, ops) = &inputs[i];
        let refs: Vec<&BitRow> = ops.iter().collect();
        let want_host = host_op(*op, &refs);
        assert_eq!(*bits_of(&resp.inner.result), want_host, "request {i} vs host");
        let single_resp = single.run(BulkRequest::bitwise(*op, ops.clone()));
        assert_eq!(
            *bits_of(&resp.inner.result),
            *bits_of(&single_resp.result),
            "request {i} vs single-device path"
        );
    }
    let snap = cluster.shutdown();
    assert_eq!(snap.completed, 24);
    assert_eq!(snap.shed, 0);
}

/// Simulated fleet throughput (total bits over busiest-device makespan)
/// must scale ≥3× going from 1 to 4 devices. Stealing is off and every
/// request is identical, so round-robin sharding makes the measurement
/// deterministic (ideal scaling here is exactly 4×).
#[test]
fn sim_throughput_scales_at_least_3x_from_1_to_4_devices() {
    let throughput = |devices: usize| -> f64 {
        let cluster = DrimCluster::new(ClusterConfig {
            steal: false,
            ..ClusterConfig::tiny(devices)
        });
        let mut rng = Rng::new(42);
        let bits = 4096; // 16 chunks = 4 full waves on the tiny geometry
        let pending: Vec<_> = (0..32)
            .map(|_| {
                let a = BitRow::random(bits, &mut rng);
                let b = BitRow::random(bits, &mut rng);
                cluster.submit_blocking(BulkRequest::bitwise(BulkOp::Xnor2, vec![a, b]))
            })
            .collect();
        for p in pending {
            p.recv().expect("response");
        }
        let snap = cluster.shutdown();
        assert_eq!(snap.completed, 32);
        let tp = snap.sim_throughput_bits_per_sec();
        assert!(tp > 0.0);
        tp
    };
    let tp1 = throughput(1);
    let tp4 = throughput(4);
    let scaling = tp4 / tp1;
    assert!(
        scaling >= 3.0,
        "1→4 device scaling {scaling:.2}x below the 3x gate (tp1={tp1}, tp4={tp4})"
    );
    assert!(
        scaling <= 4.5,
        "scaling {scaling:.2}x above the 4-device ideal — accounting bug?"
    );
}

/// Flood a 2-device fleet whose admission bound is 1 in-flight request per
/// device from several producer threads. Shedding must fire (backpressure
/// is real) and every *admitted* request must complete with a correct
/// result — requests are retried until admitted, so none may be lost.
#[test]
fn no_admitted_request_lost_under_shedding() {
    const PRODUCERS: usize = 3;
    const PER_PRODUCER: usize = 40;
    let cluster = DrimCluster::new(ClusterConfig {
        admission: AdmissionConfig {
            max_inflight_per_device: 1,
        },
        ..ClusterConfig::tiny(2)
    });
    let verified = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for p in 0..PRODUCERS {
            let cluster = &cluster;
            let verified = &verified;
            scope.spawn(move || {
                let mut rng = Rng::new(4300 + p as u64);
                for _ in 0..PER_PRODUCER {
                    let a = BitRow::random(2048, &mut rng);
                    let b = BitRow::random(2048, &mut rng);
                    let req = BulkRequest::bitwise(BulkOp::Xnor2, vec![a.clone(), b.clone()]);
                    // retry through backpressure until admitted
                    let rx = loop {
                        match cluster.try_submit(req.clone()) {
                            Ok(rx) => break rx,
                            Err(_) => std::thread::yield_now(),
                        }
                    };
                    let resp = rx.recv().expect("admitted request must complete");
                    assert_eq!(
                        *bits_of(&resp.inner.result),
                        host_op(BulkOp::Xnor2, &[&a, &b])
                    );
                    verified.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                }
            });
        }
    });
    let total = PRODUCERS * PER_PRODUCER;
    assert_eq!(verified.load(std::sync::atomic::Ordering::Relaxed), total);
    let snap = cluster.shutdown();
    assert_eq!(snap.completed as usize, total, "no admitted request lost");
    assert_eq!(snap.admitted as usize, total);
    assert_eq!(snap.merged.requests as usize, total);
    assert!(
        snap.shed > 0,
        "a 2-slot fleet hammered by {PRODUCERS} producers must shed"
    );
}

/// Requests pinned to one device's queue all complete with correct
/// results even when idle workers are allowed to steal the backlog;
/// ticket accounting (home device) survives stealing.
#[test]
fn pinned_backlog_completes_with_stealing_enabled() {
    let cluster = DrimCluster::new(ClusterConfig {
        admission: AdmissionConfig {
            max_inflight_per_device: 256,
        },
        ..ClusterConfig::tiny(4)
    });
    let mut rng = Rng::new(44);
    let mut pending = Vec::new();
    let mut inputs = Vec::new();
    for _ in 0..48 {
        let a = BitRow::random(1024, &mut rng);
        let rx = cluster
            .try_submit_to(DeviceId(0), BulkRequest::bitwise(BulkOp::Not, vec![a.clone()]))
            .expect("bound 256 fits the backlog");
        pending.push(rx);
        inputs.push(a);
    }
    for (i, p) in pending.into_iter().enumerate() {
        let resp = p.recv().expect("response");
        assert_eq!(resp.home, DeviceId(0), "ticket must stay on the home device");
        assert_eq!(
            *bits_of(&resp.inner.result),
            host_op(BulkOp::Not, &[&inputs[i]])
        );
    }
    // (per-device FIFO order itself is enforced by the scheduler's
    // exactly-one-owner invariant, covered by the scheduler unit tests —
    // response arrival order is not observable across separate receivers)
    let snap = cluster.shutdown();
    assert_eq!(snap.completed, 48);
    assert_eq!(snap.admitted, 48);
}

/// The merged fleet snapshot is consistent with per-device counters.
#[test]
fn fleet_snapshot_merges_consistently() {
    let cluster = DrimCluster::new(ClusterConfig::tiny(3));
    let mut rng = Rng::new(45);
    let pending: Vec<_> = (0..15)
        .map(|_| {
            let a = BitRow::random(3000, &mut rng);
            let b = BitRow::random(3000, &mut rng);
            cluster.submit_blocking(BulkRequest::bitwise(BulkOp::Xor2, vec![a, b]))
        })
        .collect();
    for p in pending {
        p.recv().expect("response");
    }
    let snap = cluster.shutdown();
    assert_eq!(snap.devices(), 3);
    let req_sum: u64 = snap.per_device.iter().map(|d| d.requests).sum();
    let bit_sum: u64 = snap.per_device.iter().map(|d| d.result_bits).sum();
    assert_eq!(snap.merged.requests, req_sum);
    assert_eq!(snap.merged.requests, 15);
    assert_eq!(snap.merged.result_bits, bit_sum);
    assert_eq!(snap.merged.result_bits, 15 * 3000);
    let sim_max = snap.per_device.iter().map(|d| d.sim_ns).max().unwrap();
    assert_eq!(snap.merged.sim_ns, sim_max, "fleet makespan is the busiest device");
    assert!(snap.mean_queue_wait_ns >= 0.0);
    assert_eq!(snap.completed, 15);
}
