//! Golden regression for the `drim cluster` reporting tables: pins the
//! header set, row labels, and row *shape* of the `--locality` and
//! `--capacity` sweeps so CLI reporting cannot silently drift. Timings
//! and counters are deliberately NOT pinned — only structure.
//!
//! The `--json` exporters get the same treatment: the *schema* (key set
//! and nesting) of `drim cluster --json` and `drim trace --json` is
//! pinned; values are not.

use std::process::Command;

use drim::obs::Json;

fn run(args: &[&str]) -> String {
    let out = Command::new(env!("CARGO_BIN_EXE_drim"))
        .args(args)
        .output()
        .expect("spawn drim");
    assert!(
        out.status.success(),
        "drim {args:?} failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8(out.stdout).expect("utf8 stdout")
}

/// Split one fixed-width table line into cells (columns are separated by
/// runs of ≥2 spaces; within-cell text only ever has single spaces).
fn cells(line: &str) -> Vec<String> {
    line.split("  ")
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(str::to_string)
        .collect()
}

/// Locate the table whose header line starts with `first_header` (and is
/// followed by the dash rule, distinguishing it from prose mentioning the
/// same word) and return (header cells, data-row cells).
fn table_of(out: &str, first_header: &str) -> (Vec<String>, Vec<Vec<String>>) {
    let lines: Vec<&str> = out.lines().collect();
    let hdr = (0..lines.len().saturating_sub(1))
        .find(|&i| {
            lines[i].trim_start().starts_with(first_header)
                && lines[i + 1].trim_start().starts_with('-')
        })
        .unwrap_or_else(|| panic!("no `{first_header}` table in:\n{out}"));
    let rows = lines[hdr + 2..]
        .iter()
        .take_while(|l| !l.trim().is_empty() && !l.trim_start().starts_with('→'))
        .map(|l| cells(l))
        .collect();
    (cells(lines[hdr]), rows)
}

#[test]
fn cluster_locality_table_shape_is_pinned() {
    let out = run(&[
        "cluster",
        "--locality",
        "--devices",
        "2",
        "--requests",
        "8",
        "--bits",
        "2048",
        "--seed",
        "1",
    ]);
    let (headers, rows) = table_of(&out, "placement");
    assert_eq!(
        headers,
        vec![
            "placement",
            "hits",
            "misses",
            "copied KB",
            "copy cycles",
            "makespan (compute)",
            "makespan (+copy)",
        ],
        "locality table headers drifted:\n{out}"
    );
    let labels: Vec<&str> = rows.iter().map(|r| r[0].as_str()).collect();
    assert_eq!(
        labels,
        vec![
            "carried (round-robin)",
            "resident 50%",
            "resident 80%",
            "resident 100%",
        ],
        "locality row labels drifted:\n{out}"
    );
    for r in &rows {
        assert_eq!(r.len(), headers.len(), "ragged locality row {r:?}:\n{out}");
        assert!(r[6].ends_with("µs"), "makespan cell {r:?} lost its unit");
    }
}

#[test]
fn cluster_coalesce_table_shape_is_pinned() {
    let out = run(&[
        "cluster",
        "--coalesce",
        "--devices",
        "2",
        "--requests",
        "12",
        "--bits",
        "4096",
        "--seed",
        "1",
    ]);
    let (headers, rows) = table_of(&out, "mode");
    assert_eq!(
        headers,
        vec![
            "mode",
            "waves",
            "occupancy",
            "coalesced",
            "waves saved",
            "makespan",
        ],
        "coalesce table headers drifted:\n{out}"
    );
    let labels: Vec<&str> = rows.iter().map(|r| r[0].as_str()).collect();
    assert_eq!(
        labels,
        vec!["coalesce off", "coalesce on"],
        "coalesce row labels drifted:\n{out}"
    );
    for r in &rows {
        assert_eq!(r.len(), headers.len(), "ragged coalesce row {r:?}:\n{out}");
        assert!(r[2].ends_with('%'), "occupancy cell {r:?} lost its unit");
        assert!(r[5].ends_with("µs"), "makespan cell {r:?} lost its unit");
    }
}

#[test]
fn cluster_capacity_table_shape_is_pinned() {
    let out = run(&[
        "cluster",
        "--capacity",
        "--devices",
        "2",
        "--regions",
        "6",
        "--requests",
        "12",
        "--bits",
        "4096",
        "--seed",
        "1",
    ]);
    let (headers, rows) = table_of(&out, "capacity");
    assert_eq!(
        headers,
        vec![
            "capacity",
            "policy",
            "evictions",
            "requeues",
            "hits",
            "misses",
            "copied KB",
            "makespan (+copy)",
        ],
        "capacity table headers drifted:\n{out}"
    );
    let labels: Vec<(&str, &str)> = rows
        .iter()
        .map(|r| (r[0].as_str(), r[1].as_str()))
        .collect();
    assert_eq!(
        labels,
        vec![
            ("unbounded", "single-copy"),
            ("unbounded", "replicate"),
            ("1.0x share", "lru evict"),
            ("0.5x share", "lru evict"),
        ],
        "capacity row labels drifted:\n{out}"
    );
    for r in &rows {
        assert_eq!(r.len(), headers.len(), "ragged capacity row {r:?}:\n{out}");
        assert!(r[7].ends_with("µs"), "makespan cell {r:?} lost its unit");
    }
}

/// Spawn the CLI expecting failure; return (exit code, stderr).
fn run_fail(args: &[&str]) -> (Option<i32>, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_drim"))
        .args(args)
        .output()
        .expect("spawn drim");
    assert!(
        !out.status.success(),
        "drim {args:?} unexpectedly succeeded:\n{}",
        String::from_utf8_lossy(&out.stdout)
    );
    (
        out.status.code(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
    )
}

/// Drop a scenario body into the temp dir and return its path.
fn write_scenario(name: &str, body: &str) -> std::path::PathBuf {
    let p = std::env::temp_dir().join(format!("drim_golden_{name}.toml"));
    std::fs::write(&p, body).expect("write scenario file");
    p
}

/// The scenario loader's diagnostics are part of the CLI contract: a bad
/// key, a non-positive rate, and a dangling mix reference each produce a
/// line-anchored, path-anchored message on stderr and exit code 2.
#[test]
fn bench_scenario_errors_are_pinned() {
    const TENANT: &str = "[[tenants]]\nname = \"t\"\nop = \"xnor2\"\nbits = 1_024\n";
    let badkey = format!("name = \"x\"\nbogus = 1\n\n{TENANT}");
    let badrate =
        format!("name = \"x\"\n\n[arrival]\nprocess = \"poisson\"\nrate = -2.0\n\n{TENANT}");
    let badmix = format!("name = \"x\"\n\n{TENANT}\n[[cases]]\nname = \"c\"\nmix = \"nope\"\n");
    let cases: [(&str, &str, &str); 3] = [
        ("badkey", &badkey, "bogus: unknown key `bogus`"),
        (
            "badrate",
            &badrate,
            "arrival.rate: must be a positive number",
        ),
        (
            "badmix",
            &badmix,
            "unknown tenant mix `nope` (no such [[mixes]] entry)",
        ),
    ];
    for (tag, body, want) in cases {
        let path = write_scenario(tag, body);
        let (code, stderr) = run_fail(&["bench", "--scenario", path.to_str().unwrap()]);
        assert_eq!(code, Some(2), "`{tag}` must exit 2:\n{stderr}");
        assert!(
            stderr.contains(want),
            "`{tag}` diagnostic drifted (want `{want}`):\n{stderr}"
        );
        assert!(
            stderr.contains("line "),
            "`{tag}` diagnostic lost its line anchor:\n{stderr}"
        );
        assert!(
            stderr.contains(path.to_str().unwrap()),
            "`{tag}` diagnostic lost the file path:\n{stderr}"
        );
        let _ = std::fs::remove_file(path);
    }
}

#[test]
fn bench_scenario_json_schema_is_pinned() {
    let path = write_scenario(
        "probe",
        r#"
name = "golden_probe"
description = "golden schema probe"
seed = 1

[fleet]
devices = 1
workers = 2

[arrival]
requests = 8

[telemetry]
interval_ns = 10_000

[[tenants]]
name = "a"
op = "xnor2"
bits = 2_048

[[tenants]]
name = "b"
weight = 3.0
op = "not"
bits = 2_048

[[gates]]
name = "all_done"
left = "default.completed"
op = "eq"
right = 8

[[slo]]
name = "sojourn_budget"
metric = "sojourn"
budget_ns = 1_000_000_000
percentile = 95.0
window = 2
"#,
    );
    let args = ["bench", "--scenario", path.to_str().unwrap(), "--json"];
    let out = run(&args);
    let doc = Json::parse(&out).expect("bench --json must emit valid JSON");
    assert_eq!(doc.get("schema").and_then(Json::as_f64), Some(1.0));
    assert_eq!(doc.get("bench").and_then(Json::as_str), Some("golden_probe"));
    for key in ["scenario", "seed", "cases"] {
        assert!(
            doc.get("config").and_then(|c| c.get(key)).is_some(),
            "config key `{key}` missing:\n{out}"
        );
    }
    let metrics = doc.get("metrics").expect("metrics object");
    // fleet counters and per-tenant fairness, case-qualified
    for key in [
        "default.offered",
        "default.completed",
        "default.shed",
        "default.waves",
        "default.sim_makespan_ns",
        "default.stream_digest",
        "default.results_digest",
        "default.tenant.a.completed",
        "default.tenant.a.mean_sojourn_ns",
        "default.tenant.b.sojourn_inflation",
        // continuous telemetry + SLO verdict metrics ride the artifact
        "default.telemetry.samples",
        "default.telemetry.dropped",
        "default.telemetry.interval_ns",
        "default.telemetry.last_sample_ns",
        "default.slo.sojourn_budget.pass",
        "default.slo.sojourn_budget.max_burn",
        "default.slo.sojourn_budget.bad",
        "default.slo.sojourn_budget.total",
    ] {
        assert!(
            metrics.get(key).is_some(),
            "metric key `{key}` missing:\n{out}"
        );
    }
    assert_eq!(
        metrics.get("default.telemetry.interval_ns").and_then(Json::as_f64),
        Some(10_000.0),
        "telemetry interval must echo the scenario:\n{out}"
    );
    assert!(
        metrics
            .get("default.telemetry.samples")
            .and_then(Json::as_f64)
            .unwrap_or(0.0)
            >= 1.0,
        "recorder must have sampled at least one interval:\n{out}"
    );
    // a 1s sojourn budget is unreachable by an 8-request probe → SLO pass,
    // surfaced both as a metric and as a first-class gate
    assert_eq!(
        metrics.get("default.slo.sojourn_budget.pass").and_then(Json::as_f64),
        Some(1.0),
        "probe SLO must pass:\n{out}"
    );
    assert_eq!(
        doc.get("gates").and_then(|g| g.get("slo.sojourn_budget")),
        Some(&Json::Bool(true)),
        "SLO gate verdict missing or failed:\n{out}"
    );
    assert_eq!(
        metrics.get("default.completed").and_then(Json::as_f64),
        Some(8.0),
        "probe workload must complete all 8 requests:\n{out}"
    );
    assert_eq!(
        doc.get("gates").and_then(|g| g.get("all_done")),
        Some(&Json::Bool(true)),
        "gate verdict missing or failed:\n{out}"
    );
    assert_eq!(doc.get("ok"), Some(&Json::Bool(true)));
    // same seed, same scenario → byte-identical artifact JSON
    assert_eq!(run(&args), out, "bench --json not deterministic");
    let _ = std::fs::remove_file(path);
    let _ = std::fs::remove_file(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../BENCH_golden_probe.json"
    ));
}

/// Assert `obj` is a latency-distribution summary: the stable key set
/// every exporter emits for a histogram.
fn assert_latency_summary(obj: &Json, ctx: &str) {
    for key in ["count", "mean", "min", "max", "p50", "p95", "p99"] {
        assert!(
            obj.get(key).is_some(),
            "{ctx}: summary key `{key}` missing in {obj:?}"
        );
    }
    let (p50, p95, p99) = (
        obj.get("p50").and_then(Json::as_f64).unwrap(),
        obj.get("p95").and_then(Json::as_f64).unwrap(),
        obj.get("p99").and_then(Json::as_f64).unwrap(),
    );
    assert!(
        p50 <= p95 && p95 <= p99,
        "{ctx}: percentiles not monotone: {p50} {p95} {p99}"
    );
}

/// Assert `snap` carries the movement-fabric decomposition: the
/// `prefetch_hidden_ns` total plus one counter object per copy tier, in
/// the stable `MOVEMENT_TIERS` order.
fn assert_movement_schema(snap: &Json, ctx: &str) {
    let movement = snap.get("movement").expect("movement object");
    assert!(
        movement.get("prefetch_hidden_ns").is_some(),
        "{ctx}: movement.prefetch_hidden_ns missing"
    );
    let tiers = movement
        .get("tiers")
        .and_then(Json::as_arr)
        .expect("movement.tiers array");
    let labels: Vec<&str> = tiers
        .iter()
        .map(|t| t.get("tier").and_then(Json::as_str).expect("tier label"))
        .collect();
    assert_eq!(
        labels,
        vec!["same_subarray", "same_bank", "same_device", "cross_device"],
        "{ctx}: movement tier order drifted"
    );
    for t in tiers {
        for key in ["moves", "copied_bytes", "copy_cycles"] {
            assert!(
                t.get(key).is_some(),
                "{ctx}: movement tier key `{key}` missing in {t:?}"
            );
        }
    }
}

#[test]
fn cluster_json_schema_is_pinned() {
    let out = run(&[
        "cluster", "--devices", "2", "--requests", "8", "--bits", "2048", "--seed",
        "1", "--json",
    ]);
    let doc = Json::parse(&out).expect("cluster --json must emit valid JSON");
    assert_eq!(doc.get("schema").and_then(Json::as_f64), Some(1.0));
    assert_eq!(doc.get("command").and_then(Json::as_str), Some("cluster"));
    for key in ["requests", "bits", "steal", "queue_cap"] {
        assert!(
            doc.get("config").and_then(|c| c.get(key)).is_some(),
            "config key `{key}` missing:\n{out}"
        );
    }
    let runs = doc
        .get("runs")
        .and_then(Json::as_arr)
        .expect("runs array");
    assert_eq!(runs.len(), 1, "plain (non-sweep) run must have one entry");
    let snap = runs[0].get("snapshot").expect("run snapshot");
    // fleet-level counters every downstream consumer keys on
    for key in [
        "devices",
        "admitted",
        "completed",
        "steals",
        "copied_bytes",
        "evictions",
        "tombstones_compacted",
        "makespan_ns",
        "makespan_with_copy_ns",
        "telemetry",
    ] {
        assert!(
            snap.get(key).is_some(),
            "snapshot key `{key}` missing:\n{out}"
        );
    }
    // no scenario executor behind `drim cluster` → telemetry disabled
    for key in ["enabled", "samples", "dropped", "interval_ns", "last_sample_ns"] {
        assert!(
            snap.get("telemetry").and_then(|t| t.get(key)).is_some(),
            "telemetry key `{key}` missing:\n{out}"
        );
    }
    // per-tier movement counters ride on every snapshot export
    assert_movement_schema(snap, "cluster snapshot");
    // fleet + per-device latency and queue-sojourn distributions
    assert_latency_summary(
        snap.get("queue_sojourn_ns").expect("queue_sojourn_ns"),
        "fleet queue sojourn",
    );
    assert_latency_summary(
        snap.get("fleet")
            .and_then(|f| f.get("latency_ns"))
            .expect("fleet.latency_ns"),
        "fleet latency",
    );
    let per_device = snap
        .get("per_device")
        .and_then(Json::as_arr)
        .expect("per_device array");
    assert_eq!(per_device.len(), 2, "one entry per device");
    for (i, d) in per_device.iter().enumerate() {
        assert_eq!(
            d.get("device").and_then(Json::as_f64),
            Some(i as f64),
            "per_device[{i}] mislabelled"
        );
        assert_latency_summary(
            d.get("latency_ns").expect("device latency_ns"),
            &format!("device {i} latency"),
        );
        assert_latency_summary(
            d.get("queue_sojourn_ns").expect("device queue_sojourn_ns"),
            &format!("device {i} queue sojourn"),
        );
    }
}

#[test]
fn trace_json_schema_is_pinned() {
    let out = run(&[
        "trace", "--devices", "2", "--requests", "8", "--bits", "2048", "--seed",
        "1", "--top", "3", "--json",
    ]);
    let doc = Json::parse(&out).expect("trace --json must emit valid JSON");
    assert_eq!(doc.get("schema").and_then(Json::as_f64), Some(1.0));
    assert_eq!(doc.get("command").and_then(Json::as_str), Some("trace"));
    let trace = doc.get("trace").expect("trace summary");
    for key in ["events", "dropped", "stages", "slowest_waves", "telemetry"] {
        assert!(trace.get(key).is_some(), "trace key `{key}` missing:\n{out}");
    }
    // `drim trace` has no virtual clock, so its summary carries the
    // disabled all-zero telemetry block — schema present, recorder off
    let telemetry = trace.get("telemetry").unwrap();
    assert_eq!(
        telemetry.get("enabled"),
        Some(&Json::Bool(false)),
        "trace telemetry must be disabled:\n{out}"
    );
    for key in ["samples", "dropped", "interval_ns", "last_sample_ns"] {
        assert_eq!(
            telemetry.get(key).and_then(Json::as_f64),
            Some(0.0),
            "trace telemetry `{key}` must be zero:\n{out}"
        );
    }
    // stage entries carry the fixed column set (the stage list itself
    // depends on the workload and the compiled features)
    for s in trace.get("stages").and_then(Json::as_arr).unwrap() {
        for key in ["stage", "count", "total_dur_ns", "max_dur_ns"] {
            assert!(s.get(key).is_some(), "stage key `{key}` missing:\n{out}");
        }
    }
    for w in trace.get("slowest_waves").and_then(Json::as_arr).unwrap() {
        for key in ["seq", "lane", "ts_ns", "dur_ns", "waves"] {
            assert!(w.get(key).is_some(), "wave key `{key}` missing:\n{out}");
        }
    }
    // the run's fleet snapshot rides along, same schema as cluster --json
    let snap = doc.get("snapshot").expect("snapshot");
    assert_movement_schema(snap, "trace snapshot");
    assert_latency_summary(
        snap.get("queue_sojourn_ns").expect("queue_sojourn_ns"),
        "trace fleet queue sojourn",
    );
}
