//! Golden regression for the `drim cluster` reporting tables: pins the
//! header set, row labels, and row *shape* of the `--locality` and
//! `--capacity` sweeps so CLI reporting cannot silently drift. Timings
//! and counters are deliberately NOT pinned — only structure.

use std::process::Command;

fn run(args: &[&str]) -> String {
    let out = Command::new(env!("CARGO_BIN_EXE_drim"))
        .args(args)
        .output()
        .expect("spawn drim");
    assert!(
        out.status.success(),
        "drim {args:?} failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8(out.stdout).expect("utf8 stdout")
}

/// Split one fixed-width table line into cells (columns are separated by
/// runs of ≥2 spaces; within-cell text only ever has single spaces).
fn cells(line: &str) -> Vec<String> {
    line.split("  ")
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(str::to_string)
        .collect()
}

/// Locate the table whose header line starts with `first_header` (and is
/// followed by the dash rule, distinguishing it from prose mentioning the
/// same word) and return (header cells, data-row cells).
fn table_of(out: &str, first_header: &str) -> (Vec<String>, Vec<Vec<String>>) {
    let lines: Vec<&str> = out.lines().collect();
    let hdr = (0..lines.len().saturating_sub(1))
        .find(|&i| {
            lines[i].trim_start().starts_with(first_header)
                && lines[i + 1].trim_start().starts_with('-')
        })
        .unwrap_or_else(|| panic!("no `{first_header}` table in:\n{out}"));
    let rows = lines[hdr + 2..]
        .iter()
        .take_while(|l| !l.trim().is_empty() && !l.trim_start().starts_with('→'))
        .map(|l| cells(l))
        .collect();
    (cells(lines[hdr]), rows)
}

#[test]
fn cluster_locality_table_shape_is_pinned() {
    let out = run(&[
        "cluster",
        "--locality",
        "--devices",
        "2",
        "--requests",
        "8",
        "--bits",
        "2048",
        "--seed",
        "1",
    ]);
    let (headers, rows) = table_of(&out, "placement");
    assert_eq!(
        headers,
        vec![
            "placement",
            "hits",
            "misses",
            "copied KB",
            "copy cycles",
            "makespan (compute)",
            "makespan (+copy)",
        ],
        "locality table headers drifted:\n{out}"
    );
    let labels: Vec<&str> = rows.iter().map(|r| r[0].as_str()).collect();
    assert_eq!(
        labels,
        vec![
            "carried (round-robin)",
            "resident 50%",
            "resident 80%",
            "resident 100%",
        ],
        "locality row labels drifted:\n{out}"
    );
    for r in &rows {
        assert_eq!(r.len(), headers.len(), "ragged locality row {r:?}:\n{out}");
        assert!(r[6].ends_with("µs"), "makespan cell {r:?} lost its unit");
    }
}

#[test]
fn cluster_coalesce_table_shape_is_pinned() {
    let out = run(&[
        "cluster",
        "--coalesce",
        "--devices",
        "2",
        "--requests",
        "12",
        "--bits",
        "4096",
        "--seed",
        "1",
    ]);
    let (headers, rows) = table_of(&out, "mode");
    assert_eq!(
        headers,
        vec![
            "mode",
            "waves",
            "occupancy",
            "coalesced",
            "waves saved",
            "makespan",
        ],
        "coalesce table headers drifted:\n{out}"
    );
    let labels: Vec<&str> = rows.iter().map(|r| r[0].as_str()).collect();
    assert_eq!(
        labels,
        vec!["coalesce off", "coalesce on"],
        "coalesce row labels drifted:\n{out}"
    );
    for r in &rows {
        assert_eq!(r.len(), headers.len(), "ragged coalesce row {r:?}:\n{out}");
        assert!(r[2].ends_with('%'), "occupancy cell {r:?} lost its unit");
        assert!(r[5].ends_with("µs"), "makespan cell {r:?} lost its unit");
    }
}

#[test]
fn cluster_capacity_table_shape_is_pinned() {
    let out = run(&[
        "cluster",
        "--capacity",
        "--devices",
        "2",
        "--regions",
        "6",
        "--requests",
        "12",
        "--bits",
        "4096",
        "--seed",
        "1",
    ]);
    let (headers, rows) = table_of(&out, "capacity");
    assert_eq!(
        headers,
        vec![
            "capacity",
            "policy",
            "evictions",
            "requeues",
            "hits",
            "misses",
            "copied KB",
            "makespan (+copy)",
        ],
        "capacity table headers drifted:\n{out}"
    );
    let labels: Vec<(&str, &str)> = rows
        .iter()
        .map(|r| (r[0].as_str(), r[1].as_str()))
        .collect();
    assert_eq!(
        labels,
        vec![
            ("unbounded", "single-copy"),
            ("unbounded", "replicate"),
            ("1.0x share", "lru evict"),
            ("0.5x share", "lru evict"),
        ],
        "capacity row labels drifted:\n{out}"
    );
    for r in &rows {
        assert_eq!(r.len(), headers.len(), "ragged capacity row {r:?}:\n{out}");
        assert!(r[7].ends_with("µs"), "makespan cell {r:?} lost its unit");
    }
}
