//! Fig. 8 / Fig. 9 reproduction bands: every headline number the paper
//! quotes, asserted as a ratio band against our models, at all three
//! vector lengths the paper sweeps (2^27, 2^28, 2^29).

use drim::isa::program::BulkOp;
use drim::platforms::{all_platforms, by_name, Platform, FIG8_OPS};

const SIZES: [u64; 3] = [1 << 27, 1 << 28, 1 << 29];

fn tp(name: &str, op: BulkOp, bits: u64) -> f64 {
    by_name(name).unwrap().throughput_bits_per_sec(op, bits)
}

fn avg_ratio(a: &str, b: &str, bits: u64) -> f64 {
    FIG8_OPS
        .iter()
        .map(|&op| tp(a, op, bits) / tp(b, op, bits))
        .sum::<f64>()
        / FIG8_OPS.len() as f64
}

#[test]
fn fig8_drim_vs_cpu_71x() {
    for bits in SIZES {
        let r = avg_ratio("DRIM-R", "CPU", bits);
        assert!((50.0..110.0).contains(&r), "{bits}: {r:.1} (paper 71)");
    }
}

#[test]
fn fig8_drim_vs_gpu_8_4x() {
    for bits in SIZES {
        let r = avg_ratio("DRIM-R", "GPU", bits);
        assert!((6.0..13.0).contains(&r), "{bits}: {r:.1} (paper 8.4)");
    }
}

#[test]
fn fig8_drim_vs_prior_pims_xnor() {
    for bits in SIZES {
        let ambit = tp("DRIM-R", BulkOp::Xnor2, bits) / tp("Ambit", BulkOp::Xnor2, bits);
        let d1 = tp("DRIM-R", BulkOp::Xnor2, bits) / tp("DRISA-1T1C", BulkOp::Xnor2, bits);
        let d3 = tp("DRIM-R", BulkOp::Xnor2, bits) / tp("DRISA-3T1C", BulkOp::Xnor2, bits);
        assert!((1.9..2.8).contains(&ambit), "Ambit {ambit:.2} (paper 2.3)");
        assert!((1.4..2.4).contains(&d1), "1T1C {d1:.2} (paper 1.9)");
        assert!((3.0..4.5).contains(&d3), "3T1C {d3:.2} (paper 3.7)");
    }
}

#[test]
fn fig8_hmc_over_von_neumann() {
    // paper: HMC ≈ 25×/6.5× over CPU/GPU on average; our bandwidth model
    // lands lower for CPU (see EXPERIMENTS.md discussion) but the ordering
    // and order of magnitude must hold
    for bits in SIZES {
        let vs_cpu = avg_ratio("HMC", "CPU", bits);
        let vs_gpu = avg_ratio("HMC", "GPU", bits);
        assert!(vs_cpu > 10.0, "HMC/CPU {vs_cpu:.1}");
        assert!(vs_gpu > 1.5, "HMC/GPU {vs_gpu:.1}");
    }
}

#[test]
fn fig8_drim_s_boosts_hmc_13_5x() {
    for bits in SIZES {
        let r = avg_ratio("DRIM-S", "HMC", bits);
        assert!((8.0..20.0).contains(&r), "{r:.1} (paper 13.5)");
    }
}

#[test]
fn fig8_pim_ordering_stable_across_sizes() {
    for bits in SIZES {
        for op in FIG8_OPS {
            let pims = ["Ambit", "DRISA-1T1C", "DRISA-3T1C"];
            for p in pims {
                assert!(
                    tp("DRIM-R", op, bits) >= tp(p, op, bits) * 0.99,
                    "DRIM-R must dominate {p} on {} at {bits}",
                    op.name()
                );
            }
            // every PIM beats every Von-Neumann machine on every op
            for p in ["Ambit", "DRISA-1T1C", "DRISA-3T1C", "DRIM-R", "DRIM-S"] {
                for v in ["CPU", "GPU"] {
                    assert!(tp(p, op, bits) > tp(v, op, bits), "{p} vs {v} on {}", op.name());
                }
            }
        }
    }
}

#[test]
fn fig9_energy_ratios() {
    let e = |n: &str, op: BulkOp| {
        by_name(n).unwrap().energy_pj_per_kb(op).unwrap()
    };
    // XNOR2: 2.4× vs Ambit, 1.6× vs DRISA-1T1C
    let ambit = e("Ambit", BulkOp::Xnor2) / e("DRIM-R", BulkOp::Xnor2);
    assert!((2.0..2.9).contains(&ambit), "{ambit:.2} (paper 2.4)");
    let d1 = e("DRISA-1T1C", BulkOp::Xnor2) / e("DRIM-R", BulkOp::Xnor2);
    assert!((1.3..2.0).contains(&d1), "{d1:.2} (paper 1.6)");
    // add: ~2× vs Ambit, 1.7× vs DRISA-1T1C, 27× vs CPU
    let add_ambit = e("Ambit", BulkOp::Add) / e("DRIM-R", BulkOp::Add);
    assert!((1.5..2.5).contains(&add_ambit), "{add_ambit:.2} (paper ~2)");
    let add_d1 = e("DRISA-1T1C", BulkOp::Add) / e("DRIM-R", BulkOp::Add);
    assert!((1.3..2.2).contains(&add_d1), "{add_d1:.2} (paper 1.7)");
    let add_cpu = e("CPU", BulkOp::Add) / e("DRIM-R", BulkOp::Add);
    assert!((20.0..34.0).contains(&add_cpu), "{add_cpu:.1} (paper 27)");
}

#[test]
fn fig9_ddr4_copy_69x() {
    let m = drim::energy::EnergyModel::default();
    let r = m.ddr4_copy_pj(8192.0)
        / m.aap_pj(drim::dram::command::AapKind::Copy, 8192);
    assert!((60.0..80.0).contains(&r), "{r:.1} (paper 69)");
}

#[test]
fn energy_never_negative_or_zero() {
    for p in all_platforms() {
        for op in [BulkOp::Copy, BulkOp::Not, BulkOp::Xnor2, BulkOp::Add] {
            if let Some(e) = p.energy_pj_per_kb(op) {
                assert!(e > 0.0, "{} {}", p.name(), op.name());
            }
        }
    }
}

#[test]
fn throughput_monotone_in_vector_size() {
    for p in all_platforms() {
        let p: &dyn Platform = p.as_ref();
        for op in FIG8_OPS {
            let t27 = p.throughput_bits_per_sec(op, SIZES[0]);
            let t29 = p.throughput_bits_per_sec(op, SIZES[2]);
            assert!(t29 >= t27 * 0.999, "{} {}", p.name(), op.name());
        }
    }
}
