//! End-to-end tests for `drim perf` — the perf-trajectory toolkit over
//! `BENCH_*.json` artifacts. Pins the CI contract: `check` exits 0 when
//! the current artifacts match the baselines, 1 when a metric regresses
//! beyond tolerance or a gate goes pass→fail, 2 on usage errors.

use std::path::{Path, PathBuf};
use std::process::Command;

/// A plausible bench artifact (the exact shape `BenchReport` writes).
/// `mean_ns` and `gate` are the injection points for the regression
/// tests; everything else stays fixed between baseline and current.
fn artifact(mean_ns: f64, gate: bool) -> String {
    format!(
        r#"{{
  "schema": 1,
  "bench": "trajectory_probe",
  "config": {{"devices": 2}},
  "metrics": {{
    "work": {{"mean_ns": {mean_ns}, "stddev_ns": 40.0, "min_ns": 950.0, "rate_per_sec": 1000000.0}},
    "sim_makespan_ns": 5000,
    "throughput_bits_per_sec": 2000000000.0
  }},
  "gates": {{"fast_enough": {gate}}},
  "ok": {gate}
}}
"#
    )
}

/// Fresh per-test scratch directory under the system temp dir.
fn fresh_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("drim_perf_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).expect("create scratch dir");
    d
}

fn write_artifact(dir: &Path, body: &str) -> PathBuf {
    let p = dir.join("BENCH_trajectory_probe.json");
    std::fs::write(&p, body).expect("write artifact");
    p
}

/// Run `drim perf ...`; return (exit code, stdout, stderr).
fn perf(args: &[&str]) -> (i32, String, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_drim"))
        .arg("perf")
        .args(args)
        .output()
        .expect("spawn drim");
    (
        out.status.code().expect("exit code"),
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
    )
}

#[test]
fn check_passes_on_identical_artifacts() {
    let bdir = fresh_dir("ident_base");
    let cdir = fresh_dir("ident_cur");
    write_artifact(&bdir, &artifact(1000.0, true));
    write_artifact(&cdir, &artifact(1000.0, true));
    let (code, stdout, stderr) = perf(&[
        "check",
        "--baseline",
        bdir.to_str().unwrap(),
        "--dir",
        cdir.to_str().unwrap(),
    ]);
    assert_eq!(code, 0, "identical artifacts must pass:\n{stdout}\n{stderr}");
    assert!(
        stdout.contains("PASS trajectory_probe"),
        "verdict line missing:\n{stdout}"
    );
}

#[test]
fn check_fails_on_injected_wall_time_regression() {
    let bdir = fresh_dir("regress_base");
    let cdir = fresh_dir("regress_cur");
    write_artifact(&bdir, &artifact(1000.0, true));
    // +50% mean wall time: far beyond the 10% default tolerance
    write_artifact(&cdir, &artifact(1500.0, true));
    let (code, stdout, _) = perf(&[
        "check",
        "--baseline",
        bdir.to_str().unwrap(),
        "--dir",
        cdir.to_str().unwrap(),
    ]);
    assert_eq!(code, 1, "regression must exit 1:\n{stdout}");
    assert!(
        stdout.contains("FAIL trajectory_probe"),
        "verdict line missing:\n{stdout}"
    );
    assert!(
        stdout.contains("work.mean_ns"),
        "regressed key must be named:\n{stdout}"
    );
    // the same delta passes under an explicit generous tolerance
    let (code, stdout, _) = perf(&[
        "check",
        "--baseline",
        bdir.to_str().unwrap(),
        "--dir",
        cdir.to_str().unwrap(),
        "--tolerance",
        "60",
    ]);
    assert_eq!(code, 0, "60% tolerance must absorb +50%:\n{stdout}");
}

#[test]
fn check_fails_on_gate_regression_alone() {
    let bdir = fresh_dir("gate_base");
    let cdir = fresh_dir("gate_cur");
    write_artifact(&bdir, &artifact(1000.0, true));
    write_artifact(&cdir, &artifact(1000.0, false)); // metrics flat, gate broken
    let (code, stdout, _) = perf(&[
        "check",
        "--baseline",
        bdir.to_str().unwrap(),
        "--dir",
        cdir.to_str().unwrap(),
    ]);
    assert_eq!(code, 1, "pass→fail gate must exit 1:\n{stdout}");
    assert!(stdout.contains("fast_enough"), "gate must be named:\n{stdout}");
}

#[test]
fn diff_renders_deltas_and_exits_by_verdict() {
    let dir = fresh_dir("diff");
    let base = dir.join("BENCH_a.json");
    let cur = dir.join("BENCH_b.json");
    std::fs::write(&base, artifact(1000.0, true)).unwrap();
    std::fs::write(&cur, artifact(1000.0, true)).unwrap();
    let (code, stdout, _) = perf(&["diff", base.to_str().unwrap(), cur.to_str().unwrap()]);
    assert_eq!(code, 0, "identical diff must exit 0:\n{stdout}");
    for key in ["work.mean_ns", "sim_makespan_ns", "throughput_bits_per_sec"] {
        assert!(stdout.contains(key), "delta row `{key}` missing:\n{stdout}");
    }
    assert!(
        !stdout.contains("work.stddev_ns"),
        "stddev is noise and must not be a trajectory row:\n{stdout}"
    );
    std::fs::write(&cur, artifact(1500.0, true)).unwrap();
    let (code, stdout, _) = perf(&["diff", base.to_str().unwrap(), cur.to_str().unwrap()]);
    assert_eq!(code, 1, "regressed diff must exit 1:\n{stdout}");
    assert!(stdout.contains("REGRESSED"), "verdict column missing:\n{stdout}");
}

#[test]
fn list_inventories_a_directory() {
    let dir = fresh_dir("list");
    write_artifact(&dir, &artifact(1000.0, true));
    let (code, stdout, _) = perf(&["list", dir.to_str().unwrap()]);
    assert_eq!(code, 0, "{stdout}");
    assert!(
        stdout.contains("BENCH_trajectory_probe.json") && stdout.contains("trajectory_probe"),
        "artifact row missing:\n{stdout}"
    );
}

#[test]
fn usage_errors_exit_2() {
    let (code, _, stderr) = perf(&["check"]); // no --baseline
    assert_eq!(code, 2, "{stderr}");
    assert!(stderr.contains("--baseline"), "{stderr}");
    let (code, _, stderr) = perf(&["frobnicate"]);
    assert_eq!(code, 2, "{stderr}");
    let empty = fresh_dir("empty");
    let (code, _, stderr) = perf(&["check", "--baseline", empty.to_str().unwrap()]);
    assert_eq!(code, 2, "empty baseline dir is a setup error:\n{stderr}");
}
