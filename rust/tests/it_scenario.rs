//! Integration: the scenario harness end to end — a two-tenant fleet
//! where the heavy tenant offers 10x the light tenant's volume at 4x the
//! operand size, under open-loop Poisson arrivals with a diurnal second
//! half. The heavy tenant runs under an inflight quota; the executor must
//! shed its overload while the light tenant completes everything with
//! bounded virtual-clock sojourn inflation — and the whole run must be
//! byte-deterministic.

use drim::scenario::{run_scenario, ScenarioSpec};

const TWO_TENANT: &str = r#"
name = "it_two_tenant"
description = "light tenant vs 10x heavy tenant under quota shedding"
seed = 0x17_FA12

[fleet]
devices = 2
workers = 2

[arrival]
requests = 88
process = "poisson"
rate = 1_000_000.0
window = 16

[[arrival.phases]]
frac = 0.5
rate_scale = 1.0

[[arrival.phases]]
frac = 0.5
rate_scale = 2.0

[[tenants]]
name = "light"
weight = 1.0
op = "xnor2"
bits = 65_536

[[tenants]]
name = "heavy"
weight = 10.0
op = "xnor2"
bits = 262_144
max_inflight = 8
"#;

#[test]
fn two_tenant_quota_protects_the_light_tenant() {
    let spec = ScenarioSpec::parse_str(TWO_TENANT).expect("scenario parses");
    let outcome = run_scenario(&spec);
    assert_eq!(outcome.cases.len(), 1, "implicit default case");
    let case = &outcome.cases[0];

    let tenant = |name: &str| {
        case.snapshot
            .fairness
            .iter()
            .find(|b| b.tenant == name)
            .unwrap_or_else(|| panic!("no `{name}` fairness entry"))
    };
    let light = tenant("light");
    let heavy = tenant("heavy");

    // largest-remainder apportionment of 88 requests at weights 1:10
    assert_eq!(light.offered, 8, "light share of the stream");
    assert_eq!(heavy.offered, 80, "heavy share of the stream");
    assert_eq!(light.offered + heavy.offered, 88);

    // the quota bites only the tenant that owns it
    assert_eq!(light.shed, 0, "light tenant has no quota and never sheds");
    assert!(
        heavy.shed > 0,
        "heavy tenant must shed against its inflight quota of 8"
    );
    assert_eq!(light.completed, 8, "every light request is served");
    assert_eq!(
        heavy.admitted, heavy.completed,
        "admitted heavy requests are never lost"
    );

    // bounded interference: the light tenant queues behind at most the
    // quota-bounded heavy backlog, so its mean sojourn stays within two
    // orders of magnitude of its own service time
    assert!(
        light.sojourn_inflation >= 1.0,
        "inflation below 1.0 is unphysical: {}",
        light.sojourn_inflation
    );
    assert!(
        light.sojourn_inflation < 100.0,
        "light tenant starved: inflation {}",
        light.sojourn_inflation
    );
    assert!(
        light.max_sojourn_ns >= light.mean_sojourn_ns,
        "max sojourn below the mean"
    );
}

/// Validation edge cases that used to slip through to the stream
/// generator: a zero Poisson rate divides the virtual clock by zero, and
/// an explicitly empty `phases = []` silently behaves like an unscaled
/// stream. Both must die at parse time with a line-anchored error.
#[test]
fn spec_rejects_degenerate_arrival_configs() {
    let zero_rate = r#"
name = "bad"
[arrival]
process = "poisson"
rate = 0.0
"#;
    let err = ScenarioSpec::parse_str(zero_rate).expect_err("rate 0 must fail");
    assert!(
        err.to_string().contains("arrival.rate"),
        "error should anchor the offending key: {err}"
    );

    let negative_rate = r#"
name = "bad"
[arrival]
process = "poisson"
rate = -5.0
"#;
    ScenarioSpec::parse_str(negative_rate).expect_err("negative rate must fail");

    let empty_phases = r#"
name = "bad"
[arrival]
process = "poisson"
rate = 100.0
phases = []
"#;
    let err = ScenarioSpec::parse_str(empty_phases).expect_err("phases = [] must fail");
    assert!(
        err.to_string().contains("arrival.phases"),
        "error should anchor the offending key: {err}"
    );

    // phases scale an arrival *rate*; sequential has none, so the key is
    // rejected like the other rate-family knobs
    let sequential_phases = r#"
name = "bad"
[arrival]
process = "sequential"

[[arrival.phases]]
frac = 1.0
"#;
    let err =
        ScenarioSpec::parse_str(sequential_phases).expect_err("sequential + phases must fail");
    assert!(
        err.to_string().contains("arrival.phases"),
        "error should anchor the offending key: {err}"
    );

    let zero_scale = r#"
name = "bad"
[arrival]
process = "poisson"
rate = 100.0

[[arrival.phases]]
frac = 0.5
rate_scale = 0.0
"#;
    ScenarioSpec::parse_str(zero_scale).expect_err("rate_scale 0 must fail");
}

#[test]
fn two_tenant_run_is_byte_deterministic() {
    let spec = ScenarioSpec::parse_str(TWO_TENANT).expect("scenario parses");
    let a = run_scenario(&spec);
    let b = run_scenario(&spec);
    for (ca, cb) in a.cases.iter().zip(&b.cases) {
        assert_eq!(
            ca.snapshot.to_deterministic_json().to_string_compact(),
            cb.snapshot.to_deterministic_json().to_string_compact(),
            "case `{}` diverged between identical runs",
            ca.name
        );
        assert_eq!(ca.metrics, cb.metrics, "case `{}` metrics diverged", ca.name);
    }
}
