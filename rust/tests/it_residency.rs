//! Residency-layer integration: placement-aware routing over the fleet.
//!
//! Covers the acceptance gates for data-resident routing:
//!   * a request whose operands are resident on the executing device is a
//!     *resident hit*: zero copied bytes, zero copy cycles, makespan
//!     unchanged by copy accounting
//!   * a request forced onto a non-owning device is charged exactly what
//!     the copy-cost model predicts (bytes, bus cycles, per-device ns)
//!   * carried (inline) operands are charged the host→device stream
//!   * routing prefers the device owning the most operand bits
//!   * unknown region handles are refused without losing tickets

mod common;

use common::{bits_of, host_op};
use drim::cluster::{
    ClusterConfig, ClusterRequest, DeviceId, DrimCluster, OperandRef, Placement,
    RegionId, RouteError,
};
use drim::coordinator::{BulkRequest, Payload};
use drim::isa::program::BulkOp;
use drim::util::bitrow::BitRow;
use drim::util::rng::Rng;

fn no_steal(n: usize) -> DrimCluster {
    DrimCluster::new(ClusterConfig {
        steal: false,
        ..ClusterConfig::tiny(n)
    })
}

/// Resident-hit requests execute on the owner and incur zero copy cost.
#[test]
fn resident_hits_are_zero_copy() {
    let cluster = no_steal(2);
    let mut rng = Rng::new(51);
    let mut inputs = Vec::new();
    let mut pending = Vec::new();
    for i in 0..6 {
        let owner = DeviceId(i % 2);
        let a = BitRow::random(2048, &mut rng);
        let b = BitRow::random(2048, &mut rng);
        let ra = cluster.register_resident(owner, Payload::Bits(a.clone()));
        let rb = cluster.register_resident(owner, Payload::Bits(b.clone()));
        let req = ClusterRequest::resident(BulkOp::Xnor2, vec![ra, rb]);
        assert_eq!(cluster.route(&req).unwrap(), Some(owner));
        pending.push((owner, cluster.submit_routed_blocking(req).unwrap()));
        inputs.push((a, b));
    }
    for (i, (owner, rx)) in pending.into_iter().enumerate() {
        let resp = rx.recv().expect("routed response");
        assert_eq!(resp.home, owner, "request {i} queued on its owner");
        assert_eq!(resp.device, owner, "request {i} executed on its owner");
        let (a, b) = &inputs[i];
        assert_eq!(*bits_of(&resp.inner.result), host_op(BulkOp::Xnor2, &[a, b]));
    }
    let snap = cluster.shutdown();
    assert_eq!(snap.resident_hits, 6);
    assert_eq!(snap.resident_misses, 0);
    assert_eq!(snap.copied_bytes, 0, "resident hits must move no bytes");
    assert_eq!(snap.copy_cycles, 0, "resident hits must burn no bus cycles");
    assert_eq!(
        snap.makespan_with_copy_ns(),
        snap.merged.sim_ns,
        "zero copy time may not stretch the makespan"
    );
}

/// A request pinned away from its operands is charged exactly what the
/// fleet's own copy-cost model predicts.
#[test]
fn forced_miss_is_charged_exactly() {
    let cluster = no_steal(2); // two ranks, one channel → same-channel copy
    let mut rng = Rng::new(52);
    let bits = 2048u64;
    let a = BitRow::random(bits as usize, &mut rng);
    let b = BitRow::random(bits as usize, &mut rng);
    let ra = cluster.register_resident(DeviceId(0), Payload::Bits(a.clone()));
    let rb = cluster.register_resident(DeviceId(0), Payload::Bits(b.clone()));
    let req = ClusterRequest::resident(BulkOp::Xnor2, vec![ra, rb]);

    // what the model says executing on dev1 should cost: both operands
    // stream from their dev0 replica
    let mut placement = Placement::default();
    placement.add_resident(ra, bits, vec![DeviceId(0)]);
    placement.add_resident(rb, bits, vec![DeviceId(0)]);
    let want = cluster.locality().charge(&placement, DeviceId(1));
    assert!(want.bytes > 0 && want.cycles > 0);

    let resp = cluster
        .submit_routed_blocking_to(DeviceId(1), req)
        .unwrap()
        .recv()
        .expect("pinned routed response");
    assert_eq!(resp.device, DeviceId(1));
    assert_eq!(*bits_of(&resp.inner.result), host_op(BulkOp::Xnor2, &[&a, &b]));

    let snap = cluster.shutdown();
    assert_eq!(snap.resident_hits, 0);
    assert_eq!(snap.resident_misses, 1);
    assert_eq!(snap.copied_bytes, want.bytes, "bytes follow the model");
    assert_eq!(snap.copy_cycles, want.cycles, "cycles follow the model");
    // the copy time lands on the executing device, not the owner
    assert_eq!(snap.copy_ns_per_device[0], 0);
    assert_eq!(snap.copy_ns_per_device[1], want.ns.round() as u64);
    assert_eq!(
        snap.makespan_with_copy_ns(),
        snap.merged.sim_ns + want.ns.round() as u64,
        "the miss stretches the makespan by exactly the modeled copy time"
    );
}

/// Carried (inline) operands pay the host→device stream wherever they run.
#[test]
fn carried_operands_pay_host_transfer() {
    let cluster = no_steal(2);
    let mut rng = Rng::new(53);
    let a = BitRow::random(4096, &mut rng);
    let b = BitRow::random(4096, &mut rng);
    let bulk = BulkRequest::bitwise(BulkOp::Xor2, vec![a.clone(), b.clone()]);
    let operand_bits = bulk.operand_bits() as u64;
    let req = ClusterRequest::carried(bulk);

    let want_ns = cluster
        .locality()
        .model
        .host_to_device_ns(operand_bits);
    let resp = cluster.run_routed(req).unwrap();
    assert_eq!(*bits_of(&resp.inner.result), host_op(BulkOp::Xor2, &[&a, &b]));

    let snap = cluster.shutdown();
    assert_eq!(snap.resident_misses, 1, "carried operands are never hits");
    assert_eq!(snap.copied_bytes, operand_bits / 8);
    assert_eq!(
        snap.copy_ns_per_device[resp.device.0],
        want_ns.round() as u64
    );
}

/// Mixed operands: the resident one pulls the request to its owner, and
/// only the inline one is charged.
#[test]
fn mixed_operands_route_to_owner_and_charge_only_inline() {
    let cluster = no_steal(2);
    let mut rng = Rng::new(54);
    let a = BitRow::random(2048, &mut rng);
    let b = BitRow::random(2048, &mut rng);
    let ra = cluster.register_resident(DeviceId(1), Payload::Bits(a.clone()));
    let req = ClusterRequest::new(
        BulkOp::And2,
        vec![
            OperandRef::Resident(ra),
            OperandRef::Inline(Payload::Bits(b.clone())),
        ],
    );
    assert_eq!(cluster.route(&req).unwrap(), Some(DeviceId(1)));
    let want_ns = cluster.locality().model.host_to_device_ns(2048);

    let resp = cluster.run_routed(req).unwrap();
    assert_eq!(resp.device, DeviceId(1));
    assert_eq!(*bits_of(&resp.inner.result), host_op(BulkOp::And2, &[&a, &b]));

    let snap = cluster.shutdown();
    assert_eq!(snap.copied_bytes, 2048 / 8, "only the inline operand moves");
    assert_eq!(snap.copy_ns_per_device[1], want_ns.round() as u64);
    assert_eq!(snap.copy_ns_per_device[0], 0);
}

/// Migrating a region re-homes future routed requests (and restores the
/// zero-copy property on the new owner).
#[test]
fn migration_moves_the_preferred_executor() {
    let cluster = no_steal(2);
    let mut rng = Rng::new(55);
    let a = BitRow::random(1024, &mut rng);
    let ra = cluster.register_resident(DeviceId(0), Payload::Bits(a.clone()));
    let req = ClusterRequest::resident(BulkOp::Not, vec![ra]);
    assert_eq!(cluster.route(&req).unwrap(), Some(DeviceId(0)));
    assert!(cluster.registry().migrate(ra, DeviceId(1)).unwrap());
    assert_eq!(cluster.route(&req).unwrap(), Some(DeviceId(1)));
    let resp = cluster.run_routed(req).unwrap();
    assert_eq!(resp.device, DeviceId(1));
    assert_eq!(*bits_of(&resp.inner.result), host_op(BulkOp::Not, &[&a]));
    let snap = cluster.shutdown();
    assert_eq!(snap.resident_hits, 1);
    assert_eq!(snap.copied_bytes, 0);
}

/// Unknown handles are refused up front; no admission ticket leaks and the
/// fleet keeps serving.
#[test]
fn unknown_region_refused_cleanly() {
    let cluster = no_steal(2);
    let bogus = ClusterRequest::resident(BulkOp::Not, vec![RegionId(999_999)]);
    match cluster.try_submit_routed(bogus) {
        Err(RouteError::UnknownRegion(r)) => assert_eq!(r, RegionId(999_999)),
        other => panic!("expected UnknownRegion, got {other:?}"),
    }
    // the fleet is still fully operational afterwards
    let mut rng = Rng::new(56);
    let a = BitRow::random(512, &mut rng);
    let resp = cluster.run(BulkRequest::bitwise(BulkOp::Not, vec![a.clone()]));
    assert_eq!(*bits_of(&resp.inner.result), host_op(BulkOp::Not, &[&a]));
    let snap = cluster.shutdown();
    assert_eq!(snap.admitted, 1, "only the valid request took a ticket");
    assert_eq!(snap.completed, 1);
}

/// Majority-resident routing: with operands split across devices, the
/// request runs where most of its bits already are, and only the minority
/// share is charged.
#[test]
fn majority_owner_wins_the_route() {
    let cluster = no_steal(2);
    let mut rng = Rng::new(57);
    let a = BitRow::random(2048, &mut rng);
    let b = BitRow::random(2048, &mut rng);
    let c = BitRow::random(2048, &mut rng);
    // two operands on dev1, one on dev0 → dev1 owns the majority
    let ra = cluster.register_resident(DeviceId(1), Payload::Bits(a.clone()));
    let rb = cluster.register_resident(DeviceId(1), Payload::Bits(b.clone()));
    let rc = cluster.register_resident(DeviceId(0), Payload::Bits(c.clone()));
    let req = ClusterRequest::resident(BulkOp::Maj3, vec![ra, rb, rc]);
    assert_eq!(cluster.route(&req).unwrap(), Some(DeviceId(1)));
    let want_ns = cluster
        .locality()
        .model
        .device_to_device_ns(2048, true); // tiny(2): both ranks share channel 0

    let resp = cluster.run_routed(req).unwrap();
    assert_eq!(resp.device, DeviceId(1));
    assert_eq!(
        *bits_of(&resp.inner.result),
        host_op(BulkOp::Maj3, &[&a, &b, &c])
    );
    let snap = cluster.shutdown();
    assert_eq!(snap.resident_misses, 1, "the minority operand had to move");
    assert_eq!(snap.copied_bytes, 2048 / 8);
    assert_eq!(snap.copy_ns_per_device[1], want_ns.round() as u64);
}
