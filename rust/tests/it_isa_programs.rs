//! Table 2 micro-programs executed end-to-end on the sub-array, plus the
//! assembler round-trip *through execution* (a parsed program must compute
//! the same thing as the built one).

use drim::controller::Controller;
use drim::dram::command::RowId::{self, *};
use drim::dram::geometry::DramGeometry;
use drim::isa::program::{self, BulkOp};
use drim::isa::assemble;
use drim::util::bitrow::BitRow;
use drim::util::rng::Rng;

fn fresh() -> (Controller, Rng) {
    (Controller::new(DramGeometry::tiny()), Rng::new(0xA11CE))
}

fn load(c: &mut Controller, rows: &[(RowId, &BitRow)]) {
    for (r, v) in rows {
        c.write_row(0, 0, *r, v);
    }
}

#[test]
fn every_bulkop_program_computes_its_truth_table() {
    let (mut c, mut rng) = fresh();
    let cols = c.geometry.cols;
    let a = BitRow::random(cols, &mut rng);
    let b = BitRow::random(cols, &mut rng);
    let k = BitRow::random(cols, &mut rng);
    for op in [
        BulkOp::Copy,
        BulkOp::Not,
        BulkOp::Xnor2,
        BulkOp::Xor2,
        BulkOp::And2,
        BulkOp::Or2,
        BulkOp::Nand2,
        BulkOp::Nor2,
        BulkOp::Maj3,
        BulkOp::Min3,
    ] {
        load(&mut c, &[(Data(0), &a), (Data(1), &b), (Data(2), &k)]);
        let srcs = [Data(0), Data(1), Data(2)];
        c.exec_op(op, 0, 0, &srcs[..op.arity()], Data(5));
        let got = c.read_row(0, 0, Data(5));
        for i in (0..cols).step_by(17) {
            let (x, y, z) = (a.get(i), b.get(i), k.get(i));
            let want = match op {
                BulkOp::Copy => x,
                BulkOp::Not => !x,
                BulkOp::Xnor2 => x == y,
                BulkOp::Xor2 => x != y,
                BulkOp::And2 => x && y,
                BulkOp::Or2 => x || y,
                BulkOp::Nand2 => !(x && y),
                BulkOp::Nor2 => !(x || y),
                BulkOp::Maj3 => (x as u8 + y as u8 + z as u8) >= 2,
                BulkOp::Min3 => (x as u8 + y as u8 + z as u8) < 2,
                _ => unreachable!(),
            };
            assert_eq!(got.get(i), want, "{} bit {i}", op.name());
        }
    }
}

#[test]
fn parsed_program_executes_identically() {
    let (mut c, mut rng) = fresh();
    let cols = c.geometry.cols;
    let a = BitRow::random(cols, &mut rng);
    let b = BitRow::random(cols, &mut rng);

    let built = program::xnor2(Data(0), Data(1), Data(5));
    let text = assemble::format_program(&built);
    let parsed = assemble::parse_program("xnor2", &text).unwrap();

    load(&mut c, &[(Data(0), &a), (Data(1), &b)]);
    c.run_program(0, 0, &built);
    let want = c.read_row(0, 0, Data(5));

    let mut c2 = Controller::new(DramGeometry::tiny());
    c2.write_row(0, 0, Data(0), &a);
    c2.write_row(0, 0, Data(1), &b);
    c2.run_program(0, 0, &parsed);
    assert_eq!(c2.read_row(0, 0, Data(5)), want);
}

#[test]
fn hand_written_program_via_assembler() {
    // NOT through the DCC pair, written as assembly text
    let (mut c, mut rng) = fresh();
    let a = BitRow::random(c.geometry.cols, &mut rng);
    load(&mut c, &[(Data(3), &a)]);
    let p = assemble::parse_program(
        "not_asm",
        "# manual NOT\nAAP(d3, dcc2)\nAAP(dcc1, d4)\n",
    )
    .unwrap();
    c.run_program(0, 0, &p);
    let mut want = BitRow::zeros(c.geometry.cols);
    want.not_from(&a);
    assert_eq!(c.read_row(0, 0, Data(4)), want);
}

#[test]
fn add_slice_matches_full_adder_truth_table() {
    let (mut c, _) = fresh();
    let cols = c.geometry.cols;
    // enumerate all 8 (a, b, cin) combinations, one per bit position
    let mut a = BitRow::zeros(cols);
    let mut b = BitRow::zeros(cols);
    let mut cin = BitRow::zeros(cols);
    for i in 0..8.min(cols) {
        a.set(i, (i >> 2) & 1 == 1);
        b.set(i, (i >> 1) & 1 == 1);
        cin.set(i, i & 1 == 1);
    }
    load(&mut c, &[(Data(0), &a), (Data(1), &b), (Data(2), &cin)]);
    let p = program::full_adder(Data(0), Data(1), Data(2), Data(5), Data(6));
    c.run_program(0, 0, &p);
    let sum = c.read_row(0, 0, Data(5));
    let cout = c.read_row(0, 0, Data(6));
    for i in 0..8.min(cols) {
        let total = (a.get(i) as u8) + (b.get(i) as u8) + (cin.get(i) as u8);
        assert_eq!(sum.get(i), total & 1 == 1, "sum bit {i}");
        assert_eq!(cout.get(i), total >= 2, "carry bit {i}");
    }
}

#[test]
fn subtractor_slice_is_borrow_correct() {
    let (mut c, _) = fresh();
    let cols = c.geometry.cols;
    let mut a = BitRow::zeros(cols);
    let mut b = BitRow::zeros(cols);
    let mut cin = BitRow::zeros(cols); // carry-in of the two's-complement add
    for i in 0..8.min(cols) {
        a.set(i, (i >> 2) & 1 == 1);
        b.set(i, (i >> 1) & 1 == 1);
        cin.set(i, i & 1 == 1);
    }
    load(&mut c, &[(Data(0), &a), (Data(1), &b), (Data(2), &cin)]);
    let p = program::full_subtractor(Data(0), Data(1), Data(2), Data(5), Data(6));
    c.run_program(0, 0, &p);
    let diff = c.read_row(0, 0, Data(5));
    let cout = c.read_row(0, 0, Data(6));
    for i in 0..8.min(cols) {
        // a + !b + cin (one slice of two's-complement subtraction)
        let total = a.get(i) as u8 + (!b.get(i)) as u8 + cin.get(i) as u8;
        assert_eq!(diff.get(i), total & 1 == 1, "diff bit {i}");
        assert_eq!(cout.get(i), total >= 2, "carry bit {i}");
    }
}

#[test]
fn control_rows_survive_tra_composed_ops() {
    // AND2 consumes CTRL_ZEROS via a copy, never destructively
    let (mut c, mut rng) = fresh();
    let cols = c.geometry.cols;
    let a = BitRow::random(cols, &mut rng);
    let b = BitRow::random(cols, &mut rng);
    for _ in 0..3 {
        load(&mut c, &[(Data(0), &a), (Data(1), &b)]);
        c.exec_op(BulkOp::And2, 0, 0, &[Data(0), Data(1)], Data(5));
        c.exec_op(BulkOp::Or2, 0, 0, &[Data(0), Data(1)], Data(6));
    }
    assert_eq!(c.read_row(0, 0, program::CTRL_ZEROS).popcount(), 0);
    assert_eq!(c.read_row(0, 0, program::CTRL_ONES).popcount(), cols);
}

#[test]
fn table2_timings() {
    use drim::dram::timing::TimingParams;
    let t = TimingParams::default();
    // the paper's headline sequence timings
    assert_eq!(program::copy(Data(0), Data(1)).duration_ns(&t), 90.0);
    assert_eq!(program::not(Data(0), Data(1)).duration_ns(&t), 180.0);
    assert_eq!(program::xnor2(Data(0), Data(1), Data(2)).duration_ns(&t), 270.0);
    assert_eq!(
        program::full_adder(Data(0), Data(1), Data(2), Data(3), Data(4))
            .duration_ns(&t),
        630.0
    );
}
