//! Property-based invariants across the stack (mini-proptest harness from
//! util::prop; every failure reports a replayable seed).

use drim::cluster::{
    CapacityConfig, ClusterConfig, ClusterRequest, ClusterTask, CoalesceConfig,
    Coalescer, CopyCostModel, DeviceId, DrimCluster, EvictionPolicy, RegionId,
    ResidencyRegistry, RouteError, TaskItem,
};
use drim::controller::{Controller, RowAllocator};
use drim::coordinator::{BatchPolicy, BulkRequest, Payload, Router, ServiceConfig};
use drim::dram::command::RowId::{self, *};
use drim::dram::geometry::{DeviceCapacity, DramGeometry};
use drim::isa::program::BulkOp;
use drim::util::bitrow::BitRow;
use drim::util::prop;
use drim::util::rng::Rng;

fn rand_row(cols: usize, rng: &mut Rng) -> BitRow {
    BitRow::random(cols, rng)
}

/// XNOR is an involution through its operand: xnor(xnor(a,b), b) == a.
#[test]
fn prop_xnor_involution_in_memory() {
    prop::check("xnor_involution", 40, |rng| {
        let mut c = Controller::new(DramGeometry::tiny());
        let cols = c.geometry.cols;
        let a = rand_row(cols, rng);
        let b = rand_row(cols, rng);
        c.write_row(0, 0, Data(0), &a);
        c.write_row(0, 0, Data(1), &b);
        c.exec_op(BulkOp::Xnor2, 0, 0, &[Data(0), Data(1)], Data(2));
        c.exec_op(BulkOp::Xnor2, 0, 0, &[Data(2), Data(1)], Data(3));
        if c.read_row(0, 0, Data(3)) == a {
            Ok(())
        } else {
            Err("xnor(xnor(a,b),b) != a".into())
        }
    });
}

/// De Morgan executed entirely in-memory: NAND(a,b) == OR(!a, !b).
#[test]
fn prop_de_morgan_in_memory() {
    prop::check("de_morgan", 30, |rng| {
        let mut c = Controller::new(DramGeometry::tiny());
        let cols = c.geometry.cols;
        let a = rand_row(cols, rng);
        let b = rand_row(cols, rng);
        c.write_row(0, 0, Data(0), &a);
        c.write_row(0, 0, Data(1), &b);
        c.exec_op(BulkOp::Nand2, 0, 0, &[Data(0), Data(1)], Data(2));
        c.exec_op(BulkOp::Not, 0, 0, &[Data(0)], Data(3));
        c.exec_op(BulkOp::Not, 0, 0, &[Data(1)], Data(4));
        c.exec_op(BulkOp::Or2, 0, 0, &[Data(3), Data(4)], Data(5));
        if c.read_row(0, 0, Data(2)) == c.read_row(0, 0, Data(5)) {
            Ok(())
        } else {
            Err("NAND(a,b) != OR(!a,!b)".into())
        }
    });
}

/// MAJ3 is symmetric under operand permutation.
#[test]
fn prop_maj3_symmetry() {
    prop::check("maj3_symmetry", 25, |rng| {
        let mut c = Controller::new(DramGeometry::tiny());
        let cols = c.geometry.cols;
        let rows: Vec<BitRow> = (0..3).map(|_| rand_row(cols, rng)).collect();
        let perms: [[usize; 3]; 3] = [[0, 1, 2], [2, 0, 1], [1, 2, 0]];
        let mut outs = Vec::new();
        for (pi, p) in perms.iter().enumerate() {
            c.write_row(0, 0, Data(0), &rows[p[0]]);
            c.write_row(0, 0, Data(1), &rows[p[1]]);
            c.write_row(0, 0, Data(2), &rows[p[2]]);
            c.exec_op(
                BulkOp::Maj3,
                0,
                0,
                &[Data(0), Data(1), Data(2)],
                Data(10 + pi as u16),
            );
            outs.push(c.read_row(0, 0, Data(10 + pi as u16)));
        }
        if outs[0] == outs[1] && outs[1] == outs[2] {
            Ok(())
        } else {
            Err("MAJ3 not permutation-invariant".into())
        }
    });
}

/// add_planes then sub_planes restores the original planes (two's
/// complement round trip) for random widths.
#[test]
fn prop_add_sub_roundtrip() {
    prop::check("add_sub_roundtrip", 15, |rng| {
        let mut c = Controller::new(DramGeometry::tiny());
        let cols = c.geometry.cols;
        let bits = 1 + rng.below(12) as usize;
        let (mut ar, mut br, mut sr, mut dr) = (vec![], vec![], vec![], vec![]);
        for i in 0..bits {
            let pa = rand_row(cols, rng);
            let pb = rand_row(cols, rng);
            c.write_row(0, 0, Data(i as u16), &pa);
            c.write_row(0, 0, Data(50 + i as u16), &pb);
            ar.push(Data(i as u16));
            br.push(Data(50 + i as u16));
            sr.push(Data(100 + i as u16));
            dr.push(Data(150 + i as u16));
        }
        c.add_planes(0, 0, &ar, &br, &sr, Data(200));
        c.sub_planes(0, 0, &sr, &br, &dr, Data(201));
        for i in 0..bits {
            // compare diff planes against original a planes
            let orig = c.read_row(0, 0, ar[i]);
            let back = c.read_row(0, 0, dr[i]);
            if orig != back {
                return Err(format!("plane {i} of {bits} mismatch after a+b-b"));
            }
        }
        Ok(())
    });
}

/// Add is commutative in-memory.
#[test]
fn prop_add_commutative() {
    prop::check("add_commutative", 15, |rng| {
        let mut c = Controller::new(DramGeometry::tiny());
        let cols = c.geometry.cols;
        let bits = 1 + rng.below(8) as usize;
        let (mut ar, mut br) = (vec![], vec![]);
        for i in 0..bits {
            let pa = rand_row(cols, rng);
            let pb = rand_row(cols, rng);
            c.write_row(0, 0, Data(i as u16), &pa);
            c.write_row(0, 0, Data(50 + i as u16), &pb);
            ar.push(Data(i as u16));
            br.push(Data(50 + i as u16));
        }
        let s1: Vec<RowId> = (0..bits).map(|i| Data(100 + i as u16)).collect();
        let s2: Vec<RowId> = (0..bits).map(|i| Data(150 + i as u16)).collect();
        c.add_planes(0, 0, &ar, &br, &s1, Data(200));
        c.add_planes(0, 0, &br, &ar, &s2, Data(201));
        for i in 0..bits {
            if c.read_row(0, 0, s1[i]) != c.read_row(0, 0, s2[i]) {
                return Err(format!("a+b != b+a at plane {i}"));
            }
        }
        if c.read_row(0, 0, Data(200)) != c.read_row(0, 0, Data(201)) {
            return Err("carry differs".into());
        }
        Ok(())
    });
}

/// Allocator: groups never overlap reserved/scratch rows and survive
/// arbitrary alloc/free interleavings (further cases in the unit tests).
#[test]
fn prop_allocator_stress() {
    prop::check("allocator_stress", 20, |rng| {
        let mut a = RowAllocator::new(DramGeometry::tiny());
        let mut live: Vec<_> = Vec::new();
        for _ in 0..200 {
            if rng.bool() || live.is_empty() {
                let n = 1 + rng.below(30) as usize;
                if let Some(g) = a.alloc_group(n) {
                    for r in &g.rows {
                        if let RowId::Data(d) = r {
                            if *d >= 496 {
                                return Err(format!("reserved row {d} leaked"));
                            }
                        }
                    }
                    live.push(g);
                }
            } else {
                let g = live.swap_remove(rng.below(live.len() as u64) as usize);
                a.free_group(&g);
            }
        }
        Ok(())
    });
}

/// Router sharding: reassembled chunk spans tile the payload exactly, for
/// any payload size and geometry.
#[test]
fn prop_router_sharding_tiles_payload() {
    prop::check("router_tiles", 50, |rng| {
        let cfg = ServiceConfig {
            geometry: DramGeometry::tiny(),
            workers: 1,
            policy: BatchPolicy::Coalesce,
        };
        let r = Router::new(cfg);
        let bits = 1 + rng.below(100_000) as usize;
        let chunks = r.shard(1, bits);
        let mut covered = 0usize;
        for (i, c) in chunks.iter().enumerate() {
            if c.chunk_idx != i || c.bit_offset != covered {
                return Err(format!("chunk {i} misplaced"));
            }
            covered += c.bits;
        }
        if covered == bits {
            Ok(())
        } else {
            Err(format!("covered {covered} != {bits}"))
        }
    });
}

/// Simulated wave latency is monotone in queue size and consistent between
/// policies (coalesce ≤ immediate, equal for single requests).
#[test]
fn prop_wave_latency_monotone() {
    prop::check("wave_monotone", 40, |rng| {
        let mk = |policy| {
            Router::new(ServiceConfig {
                geometry: DramGeometry::tiny(),
                workers: 1,
                policy,
            })
        };
        let im = mk(BatchPolicy::Immediate);
        let co = mk(BatchPolicy::Coalesce);
        let a = 1 + rng.below(50) as usize;
        let b = 1 + rng.below(50) as usize;
        let op = BulkOp::Xnor2;
        let single = co.sim_latency_ns(op, &[a]);
        let both_co = co.sim_latency_ns(op, &[a, b]);
        let both_im = im.sim_latency_ns(op, &[a, b]);
        if both_co < single {
            return Err("adding work reduced latency".into());
        }
        if both_co > both_im + 1e-9 {
            return Err("coalesce slower than immediate".into());
        }
        if (im.sim_latency_ns(op, &[a]) - single).abs() > 1e-9 {
            return Err("policies differ for a single request".into());
        }
        Ok(())
    });
}

/// Residency registry bookkeeping: after ANY interleaving of register /
/// migrate / replicate / evict / remove on a capacity-bounded registry,
/// the per-device footprint counters equal the recomputed sum over
/// regions, every device stays within capacity, and no region loses its
/// last replica without being tombstoned (all folded into
/// `check_invariants`, re-verified after every single step).
#[test]
fn prop_residency_footprint_consistent_under_interleaving() {
    prop::check("residency_footprint", 25, |rng| {
        let devices = 3;
        let cap = DeviceCapacity::of_bits(4096);
        let reg = ResidencyRegistry::with_capacity(
            devices,
            CapacityConfig {
                capacity: cap,
                policy: EvictionPolicy::Lru,
            },
            CopyCostModel::default(),
        );
        let mut live: Vec<RegionId> = Vec::new();
        for step in 0..150 {
            let dev = DeviceId(rng.below(devices as u64) as usize);
            match rng.below(6) {
                0 | 1 => {
                    let bits = 64 * (1 + rng.below(8)) as usize;
                    match reg.try_register(dev, Payload::Bits(BitRow::zeros(bits))) {
                        Ok(r) => live.push(r),
                        // LRU always makes room for a region that fits
                        Err(e) => return Err(format!("step {step}: register refused: {e}")),
                    }
                }
                2 if !live.is_empty() => {
                    let r = live[rng.below(live.len() as u64) as usize];
                    reg.migrate(r, dev)
                        .map_err(|e| format!("step {step}: migrate refused: {e}"))?;
                }
                3 if !live.is_empty() => {
                    let r = live[rng.below(live.len() as u64) as usize];
                    // replication never evicts, so a full target refusing
                    // (DeviceFull) is a defined outcome, not a failure
                    let _ = reg.replicate(r, dev);
                }
                4 if !live.is_empty() => {
                    let r = live[rng.below(live.len() as u64) as usize];
                    let _ = reg.evict_from(r, dev);
                }
                5 if !live.is_empty() => {
                    let r = live.swap_remove(rng.below(live.len() as u64) as usize);
                    let _ = reg.remove(r);
                }
                _ => {}
            }
            reg.check_invariants()
                .map_err(|e| format!("step {step}: {e}"))?;
            for d in 0..devices {
                let bits = reg.resident_bits_on(DeviceId(d));
                if bits > cap.resident_bits {
                    return Err(format!("step {step}: dev{d} over capacity ({bits})"));
                }
            }
        }
        Ok(())
    });
}

/// Eviction never dangles a handle: every handle the registry ever issued
/// either resolves (region live, with a non-empty replica set) or yields
/// the defined `Evicted` error — never a panic, never `UnknownRegion`
/// (that would mean the tombstone was skipped), never a silent fallback.
#[test]
fn prop_evicted_handles_stay_defined() {
    prop::check("evicted_handles_defined", 25, |rng| {
        let devices = 2;
        let reg = ResidencyRegistry::with_capacity(
            devices,
            CapacityConfig {
                capacity: DeviceCapacity::of_bits(2048),
                policy: EvictionPolicy::Lru,
            },
            CopyCostModel::default(),
        );
        // handles "queued" by clients that may outlive their regions
        let mut queued: Vec<RegionId> = Vec::new();
        for step in 0..40 {
            let dev = DeviceId(rng.below(devices as u64) as usize);
            let bits = 256 * (1 + rng.below(4)) as usize;
            match reg.try_register(dev, Payload::Bits(BitRow::zeros(bits))) {
                Ok(r) => queued.push(r),
                Err(e) => return Err(format!("step {step}: register refused: {e}")),
            }
            for &r in &queued {
                let req = ClusterRequest::resident(BulkOp::Not, vec![r]);
                match reg.placement_of(&req) {
                    Ok(p) => {
                        if p.total_resident_bits() == 0 {
                            return Err(format!("step {step}: {r} resolved with no span"));
                        }
                        if p.resident.iter().any(|s| s.replicas.is_empty()) {
                            return Err(format!("step {step}: {r} has an empty replica set"));
                        }
                    }
                    Err(RouteError::Evicted(rr)) => {
                        if rr != r {
                            return Err(format!("step {step}: wrong region in Evicted"));
                        }
                    }
                    Err(e) => {
                        return Err(format!("step {step}: {r} undefined error {e:?}"));
                    }
                }
            }
        }
        // by the end the 2048-bit devices must have evicted something,
        // or the property never exercised its subject
        if reg.evictions() == 0 {
            return Err("no eviction ever happened".into());
        }
        Ok(())
    });
}

/// Movement-fabric pin discipline under concurrent churn: three threads
/// hammer a capacity-bounded registry with register / replicate /
/// migrate / evict / remove, and afterwards (a) `check_invariants` holds
/// (footprint counters equal the recomputed per-region sum, pins stay
/// unique), (b) no two resident rows on a device share a pinned
/// (bank, sub-array, row) coordinate, (c) every surviving replica holds
/// a pinned row on its device, and (d) no device overdrafts capacity.
#[test]
fn prop_pin_coordinates_unique_and_footprint_conserved_under_churn() {
    use std::sync::Arc;
    prop::check_seeds("movement_pins", &[0x1DEA, 0xBEEF, 0xC0A1], |rng| {
        let devices = 3usize;
        let cap = DeviceCapacity::of_bits(4096);
        let reg = Arc::new(
            ResidencyRegistry::with_capacity(
                devices,
                CapacityConfig {
                    capacity: cap,
                    policy: EvictionPolicy::Lru,
                },
                CopyCostModel::default(),
            )
            .with_geometry(DramGeometry::tiny()),
        );
        let mut handles = Vec::new();
        for t in 0..3u64 {
            let reg = Arc::clone(&reg);
            let mut trng = Rng::new(rng.next_u64() ^ (t << 32));
            handles.push(std::thread::spawn(move || -> Vec<RegionId> {
                let mut live: Vec<RegionId> = Vec::new();
                for _ in 0..80 {
                    let dev = DeviceId(trng.below(3) as usize);
                    match trng.below(6) {
                        0 | 1 => {
                            let bits = 64 * (1 + trng.below(8)) as usize;
                            // under concurrent eviction pressure a
                            // refusal is a defined outcome, not a bug
                            if let Ok(r) =
                                reg.try_register(dev, Payload::Bits(BitRow::zeros(bits)))
                            {
                                live.push(r);
                            }
                        }
                        2 if !live.is_empty() => {
                            let r = live[trng.below(live.len() as u64) as usize];
                            // another thread's register may have evicted
                            // `r` already — Evicted is a defined outcome
                            let _ = reg.migrate(r, dev);
                        }
                        3 if !live.is_empty() => {
                            let r = live[trng.below(live.len() as u64) as usize];
                            let _ = reg.replicate(r, dev);
                        }
                        4 if !live.is_empty() => {
                            let r = live[trng.below(live.len() as u64) as usize];
                            let _ = reg.evict_from(r, dev);
                        }
                        5 if !live.is_empty() => {
                            let r =
                                live.swap_remove(trng.below(live.len() as u64) as usize);
                            let _ = reg.remove(r);
                        }
                        _ => {}
                    }
                }
                live
            }));
        }
        let mut live: Vec<RegionId> = Vec::new();
        for h in handles {
            live.extend(h.join().expect("churn thread panicked"));
        }
        reg.check_invariants()
            .map_err(|e| format!("after churn: {e}"))?;
        for d in 0..devices {
            let dev = DeviceId(d);
            let pins = reg.pins_on(dev);
            let mut seen = std::collections::HashSet::new();
            for (r, c) in &pins {
                if !seen.insert((c.bank, c.subarray, c.row)) {
                    return Err(format!(
                        "device {d}: {r} pinned to an occupied row {c:?}"
                    ));
                }
            }
            let bits = reg.resident_bits_on(dev);
            if bits > cap.resident_bits {
                return Err(format!("device {d} over capacity ({bits} bits)"));
            }
        }
        // homes and pins stay parallel: every surviving replica owns a row
        for &r in &live {
            if let Some(devs) = reg.replicas(r) {
                for dev in devs {
                    if reg.pin_of(r, dev).is_none() {
                        return Err(format!(
                            "{r} resident on {dev} without a pinned row"
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

/// What one coalescer push recorded, keyed by the item's fleet sequence
/// number (the coalescer packing properties replay groups against it).
type PushedMap = std::collections::HashMap<u64, (usize, BulkOp, usize)>;

/// Verify a batch of emitted wave groups against the push log: every
/// item emerges exactly once, never packed across devices, multi-item
/// groups are single-op and conserve slots (≤ one wave), and the group's
/// wave-unit accounting matches the pushed chunk counts.
fn verify_groups(
    groups: &[ClusterTask],
    pushed: &PushedMap,
    emitted: &mut std::collections::HashSet<u64>,
    slots: usize,
    cols: usize,
) -> Result<(), String> {
    for g in groups {
        if g.items.is_empty() {
            return Err("empty wave group emitted".into());
        }
        let mut total = 0usize;
        let mut ops = Vec::new();
        for it in &g.items {
            let &(home, op, chunks) = pushed
                .get(&it.seq)
                .ok_or_else(|| format!("seq {} never pushed", it.seq))?;
            if home != g.home.0 {
                return Err(format!(
                    "seq {} pushed for dev{home} emerged on {}",
                    it.seq, g.home
                ));
            }
            if !emitted.insert(it.seq) {
                return Err(format!("seq {} emitted twice", it.seq));
            }
            total += chunks;
            ops.push(op);
        }
        if g.items.len() > 1 {
            if total > slots {
                return Err(format!(
                    "group of {total} chunks exceeds the {slots}-slot wave"
                ));
            }
            if ops.iter().any(|&o| o != ops[0]) {
                return Err("mixed ops packed into one wave group".into());
            }
        }
        if g.wave_units(cols) != total {
            return Err(format!(
                "group wave_units {} != pushed chunk total {total}",
                g.wave_units(cols)
            ));
        }
    }
    Ok(())
}

/// Coalescer packing invariants over arbitrary push sequences: slot
/// conservation (a packed group never exceeds one wave), no cross-device
/// or cross-op packing, the flush-horizon bound honored after every
/// push, and exactly-once emission once the coalescer is flushed.
#[test]
fn prop_coalescer_packing_invariants() {
    prop::check("coalescer_packing", 30, |rng| {
        let devices = 1 + rng.below(3) as usize;
        let slots = 2 + rng.below(7) as usize;
        let horizon = 1 + rng.below(12);
        let cols = 64usize;
        let coal = Coalescer::new(
            CoalesceConfig::strict(horizon),
            vec![slots; devices],
        );
        let mut pushed: PushedMap = PushedMap::new();
        let mut emitted = std::collections::HashSet::new();
        for seq in 0..60u64 {
            let home = DeviceId(rng.below(devices as u64) as usize);
            let op = if rng.bool() { BulkOp::Not } else { BulkOp::Xnor2 };
            // 0 = empty payload (bypasses), up to slots+1 (wave-filling
            // items bypass too)
            let chunks = rng.below(slots as u64 + 2) as usize;
            let operands: Vec<BitRow> = (0..op.arity())
                .map(|_| BitRow::zeros(chunks * cols))
                .collect();
            let (reply, _keep) = std::sync::mpsc::channel();
            let item = TaskItem {
                seq,
                req: BulkRequest::bitwise(op, operands),
                placement: None,
                reply,
                admitted_at: std::time::Instant::now(),
            };
            pushed.insert(seq, (home.0, op, chunks));
            let due = coal.push(home, item, chunks, false);
            verify_groups(&due, &pushed, &mut emitted, slots, cols)?;
            if coal.max_held_age() >= horizon {
                return Err(format!(
                    "held age {} breached the {horizon}-submission horizon",
                    coal.max_held_age()
                ));
            }
        }
        let rest = coal.flush_all();
        verify_groups(&rest, &pushed, &mut emitted, slots, cols)?;
        if emitted.len() != pushed.len() {
            return Err(format!(
                "{} of {} pushed items ever emerged",
                emitted.len(),
                pushed.len()
            ));
        }
        if coal.held() != 0 {
            return Err("items still staged after flush_all".into());
        }
        Ok(())
    });
}

/// Coalescing must be invisible in the results: the same seeded burst
/// through the same fleet yields byte-identical payloads with the
/// coalescer off, in strict staging, and in opportunistic staging —
/// across a fixed seed matrix.
#[test]
fn prop_coalesce_results_byte_exact() {
    prop::check_seeds("coalesce_byte_exact", &[0x1DEA, 0xBEEF, 0xC0A1], |rng| {
        let seed = rng.next_u64();
        let run = |coalesce: CoalesceConfig| -> Vec<Payload> {
            let c = DrimCluster::new(ClusterConfig {
                coalesce,
                steal: false,
                ..ClusterConfig::tiny(2)
            });
            c.pump_coalesce(12, 200, seed)
        };
        let off = run(CoalesceConfig::off());
        let strict = run(CoalesceConfig::strict(64));
        if strict != off {
            return Err("strict coalescing changed request results".into());
        }
        let opportunistic = run(CoalesceConfig::opportunistic());
        if opportunistic != off {
            return Err("opportunistic coalescing changed request results".into());
        }
        Ok(())
    });
}

/// Tracer ring overflow is lossy only at the tail: for any lane count,
/// capacity, and write volume, each lane keeps exactly its newest
/// `min(written, cap)` events in write order with payloads intact, and
/// the fleet drop counter accounts for every displaced event.
#[test]
fn prop_tracer_ring_overflow_keeps_newest_events_intact() {
    use drim::obs::{Stage, Tracer};
    prop::check("tracer_ring_overflow", 30, |rng| {
        if !cfg!(feature = "trace") {
            return Ok(()); // recording is compiled out
        }
        let lanes = 1 + rng.below(4) as usize;
        let cap = 1 + rng.below(64) as usize;
        let t = Tracer::new(lanes, cap);
        t.set_sampling(1);
        let mut lane_seqs: Vec<Vec<u64>> = vec![Vec::new(); lanes];
        let total = cap as u64 + rng.below(400);
        for seq in 0..total {
            let lane = rng.below(lanes as u64) as usize;
            // payloads derived from seq so corruption is detectable
            t.instant_with_dur(lane as u32, Stage::Admit, seq, seq * 3 + 1, seq ^ 0xA5);
            lane_seqs[lane].push(seq);
        }
        let trace = t.collect();
        let expect_dropped: u64 = lane_seqs
            .iter()
            .map(|s| (s.len() as u64).saturating_sub(cap as u64))
            .sum();
        if trace.dropped != expect_dropped {
            return Err(format!(
                "dropped {} != expected {expect_dropped}",
                trace.dropped
            ));
        }
        let expect_events: usize = lane_seqs.iter().map(|s| s.len().min(cap)).sum();
        if trace.events.len() != expect_events {
            return Err(format!(
                "{} events survived, expected {expect_events}",
                trace.events.len()
            ));
        }
        for (lane, seqs) in lane_seqs.iter().enumerate() {
            let survived: Vec<u64> = trace
                .events
                .iter()
                .filter(|e| e.lane == lane as u32)
                .map(|e| e.seq)
                .collect();
            // drop-oldest: exactly the newest min(written, cap), in order
            let keep = seqs.len().min(cap);
            if survived[..] != seqs[seqs.len() - keep..] {
                return Err(format!(
                    "lane {lane} kept {survived:?}, expected newest {keep} of {seqs:?}"
                ));
            }
        }
        for e in &trace.events {
            if e.dur_ns != e.seq * 3 + 1 || e.detail != (e.seq ^ 0xA5) {
                return Err(format!("span payload corrupted: {e:?}"));
            }
            if e.stage != Stage::Admit {
                return Err(format!("stage corrupted: {e:?}"));
            }
        }
        Ok(())
    });
}

/// Scenario harness determinism: for random specs inside the
/// deterministic envelope (no stealing, strict-or-off coalescing), the
/// arrival stream is a pure function of the spec — two generations agree
/// event-for-event and digest-for-digest, the offered wave-unit load
/// matches the declared load exactly, and two full executions of the
/// same case produce byte-identical deterministic fleet snapshots.
#[test]
fn prop_scenario_stream_and_execution_deterministic() {
    use drim::scenario::{
        generate, offered_wave_units, run_case, stream_digest, ScenarioSpec,
    };
    prop::check("scenario_deterministic", 6, |rng| {
        let seed = rng.next_u64();
        let devices = 1 + rng.below(3);
        let requests = 8 + rng.below(32);
        let process = match rng.below(3) {
            0 => "process = \"sequential\"".to_string(),
            1 => "process = \"poisson\"\nrate = 1_000_000.0".to_string(),
            _ => "process = \"burst\"\nburst_size = 4\nburst_gap_ns = 500".to_string(),
        };
        let coalesce = if rng.bool() { "strict" } else { "off" };
        let src = format!(
            r#"
name = "prop_case"
seed = {seed}

[fleet]
devices = {devices}
workers = 2

[arrival]
requests = {requests}
{process}

[runtime]
coalesce = "{coalesce}"

[[tenants]]
name = "carried"
op = "xnor2"
bits = 4_096

[[tenants]]
name = "resident"
weight = 2.0
op = "not"
bits = 4_096
placement = "resident"
regions = 6
zipf_theta = 1.2
"#
        );
        let spec = ScenarioSpec::parse_str(&src).map_err(|e| format!("parse: {e}"))?;
        let cases = spec.resolved_cases();
        let case = &cases[0];
        let a = generate(case);
        let b = generate(case);
        if a != b {
            return Err("two generations of the same case differ".into());
        }
        if stream_digest(&a) != stream_digest(&b) {
            return Err("stream digests differ".into());
        }
        let offered = offered_wave_units(case, &a);
        if offered != case.declared_wave_units() {
            return Err(format!(
                "offered {offered} wave units != declared {}",
                case.declared_wave_units()
            ));
        }
        let run1 = run_case(case)
            .snapshot
            .to_deterministic_json()
            .to_string_compact();
        let run2 = run_case(case)
            .snapshot
            .to_deterministic_json()
            .to_string_compact();
        if run1 != run2 {
            return Err("identical runs produced different deterministic snapshots".into());
        }
        Ok(())
    });
}

/// Tenant accounting conservation across the evicted-region
/// requeue → degrade-to-carried path: for every tenant breakdown,
/// `offered == admitted + shed`, every admitted request completes
/// (`admitted == completed` — blocking submission never loses one), and
/// the degraded count is a subset of completions (`degraded <=
/// completed`). Runs under capacity pressure (LRU thrash and fail-fast
/// refusal cases) plus an inflight quota so all three shedding/degrade
/// arms fire across the seed matrix.
#[test]
fn prop_tenant_accounting_conserves_requests() {
    use drim::scenario::{run_case, ScenarioSpec};
    prop::check_seeds(
        "tenant_conservation",
        &[0xACC7, 0xD156, 0x5EED_0008],
        |rng| {
            let seed = rng.next_u64();
            let requests = 48 + rng.below(32);
            let src = format!(
                r#"
name = "prop_conservation"
seed = {seed}

[fleet]
devices = 2
workers = 2

[arrival]
requests = {requests}
window = 8

[[tenants]]
name = "zipf"
op = "not"
bits = 32_768
placement = "resident"
regions = 8
zipf_theta = 1.3

[[tenants]]
name = "quota"
weight = 2.0
op = "xnor2"
bits = 16_384
max_inflight = 4

[[cases]]
name = "lru_thrash"
capacity_share = 0.5
eviction = "lru"

[[cases]]
name = "fail_fast"
capacity_share = 0.5

[[cases]]
name = "all_refused"
capacity_share = 0.1
"#
            );
            let spec = ScenarioSpec::parse_str(&src).map_err(|e| format!("parse: {e}"))?;
            for case in &spec.resolved_cases() {
                let outcome = run_case(case);
                let mut total_offered = 0u64;
                for t in &outcome.snapshot.fairness {
                    let ctx = format!("case `{}` tenant `{}`", case.name, t.tenant);
                    if t.offered != t.admitted + t.shed {
                        return Err(format!(
                            "{ctx}: offered {} != admitted {} + shed {}",
                            t.offered, t.admitted, t.shed
                        ));
                    }
                    if t.admitted != t.completed {
                        return Err(format!(
                            "{ctx}: admitted {} != completed {} (a request was lost)",
                            t.admitted, t.completed
                        ));
                    }
                    if t.degraded > t.completed {
                        return Err(format!(
                            "{ctx}: degraded {} exceeds completed {}",
                            t.degraded, t.completed
                        ));
                    }
                    total_offered += t.offered;
                }
                if total_offered != requests {
                    return Err(format!(
                        "case `{}`: tenants account for {total_offered} of {requests} arrivals",
                        case.name
                    ));
                }
                // the degrade machinery must actually fire somewhere in
                // the matrix — at a 0.1x share no region fits at all, so
                // *every* resident request deterministically falls to the
                // carried-degrade arm regardless of what the Zipf law
                // sampled (guards the path against becoming dead code)
                let zipf = outcome
                    .snapshot
                    .fairness
                    .iter()
                    .find(|t| t.tenant == "zipf")
                    .expect("zipf breakdown");
                if case.name == "all_refused" && zipf.degraded != zipf.completed {
                    return Err(format!(
                        "all-refused case: every resident completion should be \
                         degraded, got {} of {}",
                        zipf.degraded, zipf.completed
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Continuous-telemetry merge commutativity: a random virtual-clock
/// observation stream split across two recorders folds back into the
/// identical series regardless of merge order, and matches a single
/// recorder that saw the whole stream — byte-for-byte on the serialized
/// series (the same JSON the exporters emit).
#[test]
fn prop_timeseries_merge_order_independent() {
    use drim::obs::TimeSeriesRecorder;
    prop::check("timeseries_merge", 30, |rng| {
        let interval = 1 + rng.below(5_000);
        let lanes = vec!["a".to_string(), "b".to_string()];
        // capacity large enough that no split evicts: order independence
        // is exact below the eviction horizon
        let mk = || TimeSeriesRecorder::new(interval, 512, 2, lanes.clone());
        let (mut a, mut b, mut whole) = (mk(), mk(), mk());
        let n = 50 + rng.below(200);
        for _ in 0..n {
            // stay within 256 buckets of t=0 so capacity 512 never evicts
            let t = rng.below(interval * 256);
            let split = rng.bool();
            let kind = rng.below(3);
            let lane = rng.below(2) as usize;
            let sojourn = rng.below(1_000_000);
            let busy = rng.below(interval);
            let depth = rng.below(48) as usize;
            let admitted = rng.bool();
            for rec in [if split { &mut a } else { &mut b }, &mut whole] {
                match kind {
                    0 => rec.record_arrival(t, admitted),
                    1 => rec.record_completion(t, lane, sojourn, busy),
                    _ => rec.record_queue_depth(t, depth),
                }
            }
        }
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        let whole_json = whole.to_json().to_string_compact();
        if ab.to_json().to_string_compact() != whole_json {
            return Err("merge(a,b) differs from the unsplit recorder".into());
        }
        if ba.to_json().to_string_compact() != whole_json {
            return Err("merge(b,a) differs from the unsplit recorder".into());
        }
        Ok(())
    });
}

/// Telemetry percentile sanity across merged samples: in every interval
/// of a merged series, the fleet-merged sojourn percentile curve is
/// monotone in p, bounded by the interval's min/max, and the cumulative
/// counters are monotone along the timeline with conserved deltas
/// (offered == admitted + shed).
#[test]
fn prop_timeseries_percentiles_monotone_across_merge() {
    use drim::obs::TimeSeriesRecorder;
    prop::check("timeseries_percentiles", 25, |rng| {
        let interval = 1_000u64;
        let lanes = vec!["a".to_string(), "b".to_string()];
        let mk = || TimeSeriesRecorder::new(interval, 256, 2, lanes.clone());
        let (mut a, mut b) = (mk(), mk());
        for _ in 0..(100 + rng.below(200)) {
            let rec = if rng.bool() { &mut a } else { &mut b };
            let t = rng.below(interval * 64);
            rec.record_arrival(t, rng.bool());
            rec.record_completion(
                t,
                rng.below(2) as usize,
                rng.below(5_000_000),
                rng.below(interval),
            );
        }
        a.merge(&b);
        let samples = a.samples();
        let mut prev_offered = 0u64;
        let mut prev_completed = 0u64;
        for s in &samples {
            if s.offered < prev_offered || s.completed < prev_completed {
                return Err(format!("cumulative counter went backwards at t={}", s.t_ns));
            }
            prev_offered = s.offered;
            prev_completed = s.completed;
            if s.d_offered != s.d_admitted + s.d_shed {
                return Err(format!(
                    "t={}: offered delta {} != admitted {} + shed {}",
                    s.t_ns, s.d_offered, s.d_admitted, s.d_shed
                ));
            }
            let h = s.sojourn_merged();
            if h.is_empty() {
                continue;
            }
            let mut prev = 0.0f64;
            for p in [1.0, 25.0, 50.0, 75.0, 90.0, 95.0, 99.0, 99.9] {
                let v = h.percentile(p);
                if v + 1e-9 < prev {
                    return Err(format!(
                        "t={}: percentile curve dipped at p{p}: {v} < {prev}",
                        s.t_ns
                    ));
                }
                prev = v;
            }
            if (h.percentile(99.9) as u64) > h.max().saturating_mul(2) {
                return Err(format!(
                    "t={}: p99.9 {} implausibly above max {}",
                    s.t_ns,
                    h.percentile(99.9),
                    h.max()
                ));
            }
        }
        Ok(())
    });
}

/// DRA destructiveness: after any DRA, the two source cells and the
/// destination agree (the array's own write-back invariant).
#[test]
fn prop_dra_writeback_consistency() {
    prop::check("dra_writeback", 30, |rng| {
        use drim::dram::command::AapKind;
        use drim::subarray::SubArray;
        let cols = 64 + rng.below(512) as usize;
        let mut s = SubArray::new(cols);
        s.write_row(X(1), &rand_row(cols, rng));
        s.write_row(X(2), &rand_row(cols, rng));
        let out = s.execute_aap(AapKind::Dra, &[X(1), X(2)], &[Data(0)]);
        if s.read_row(X(1)) == out && s.read_row(X(2)) == out && s.read_row(Data(0)) == out
        {
            Ok(())
        } else {
            Err("cells and destination diverge after DRA".into())
        }
    });
}
