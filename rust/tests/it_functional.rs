//! Cross-layer functional integration: sub-array charge sharing vs the
//! analog decision models, controller execution on full-size geometry.

use drim::analog::{dra_sense, model, tra_sense};
use drim::analog::params as P;
use drim::controller::Controller;
use drim::dram::command::{AapKind, RowId::*};
use drim::dram::geometry::DramGeometry;
use drim::isa::program::BulkOp;
use drim::subarray::sense::{dra_decision, tra_decision};
use drim::subarray::SubArray;
use drim::util::bitrow::BitRow;
use drim::util::rng::Rng;

/// The digital SA decision table must equal the zero-variation analog
/// model — the two layers describe the same circuit.
#[test]
fn digital_matches_analog_decisions() {
    for n in 0..=2usize {
        let (di, dj) = match n {
            0 => (0.0, 0.0),
            1 => (1.0, 0.0),
            _ => (1.0, 1.0),
        };
        let (xnor_analog, xor_analog) = dra_sense(
            di * P::VDD,
            dj * P::VDD,
            1.0,
            1.0,
            P::CP_RATIO,
            P::VS_LOW,
            P::VS_HIGH,
            0.0,
        );
        assert_eq!((xnor_analog, xor_analog), dra_decision(n), "DRA n={n}");
    }
    for n in 0..=3usize {
        let q: Vec<f64> = (0..3).map(|i| ((i < n) as u8) as f64 * P::VDD).collect();
        let maj = tra_sense([q[0], q[1], q[2]], [1.0; 3], P::CB_RATIO, P::VSA, 0.0);
        assert_eq!(maj, tra_decision(n), "TRA n={n}");
    }
}

/// The margins that make Table 3 work, measured from the ideal levels.
#[test]
fn margin_geometry() {
    assert!(model::dra_worst_margin() > 1.5 * model::tra_worst_margin());
}

#[test]
fn full_geometry_controller_xnor() {
    let mut c = Controller::new(DramGeometry::default());
    let mut rng = Rng::new(1);
    let a = BitRow::random(8192, &mut rng);
    let b = BitRow::random(8192, &mut rng);
    c.write_row(3, 17, Data(100), &a);
    c.write_row(3, 17, Data(101), &b);
    c.exec_op(BulkOp::Xnor2, 3, 17, &[Data(100), Data(101)], Data(102));
    let mut want = BitRow::zeros(8192);
    want.apply2(&a, &b, |x, y| !(x ^ y));
    assert_eq!(c.read_row(3, 17, Data(102)), want);
    // untouched sub-arrays are untouched
    assert_eq!(c.read_row(3, 18, Data(100)).popcount(), 0);
}

#[test]
fn dra_write_back_is_visible_in_cells() {
    // Fig. 6: after DRA, both source cells hold the XNOR result
    let mut s = SubArray::new(1024);
    let mut rng = Rng::new(2);
    let a = BitRow::random(1024, &mut rng);
    let b = BitRow::random(1024, &mut rng);
    s.write_row(X(1), &a);
    s.write_row(X(2), &b);
    s.execute_aap(AapKind::Dra, &[X(1), X(2)], &[Data(0)]);
    let mut xnor = BitRow::zeros(1024);
    xnor.apply2(&a, &b, |x, y| !(x ^ y));
    assert_eq!(s.read_row(X(1)), xnor);
    assert_eq!(s.read_row(X(2)), xnor);
    assert_eq!(s.read_row(Data(0)), xnor);
}

#[test]
fn tra_write_back_is_visible_in_cells() {
    let mut s = SubArray::new(512);
    let mut rng = Rng::new(3);
    let rows: Vec<BitRow> = (0..3).map(|_| BitRow::random(512, &mut rng)).collect();
    s.write_row(X(1), &rows[0]);
    s.write_row(X(2), &rows[1]);
    s.write_row(X(3), &rows[2]);
    s.execute_aap(AapKind::Tra, &[X(1), X(2), X(3)], &[Data(7)]);
    let mut maj = BitRow::zeros(512);
    maj.apply3(&rows[0], &rows[1], &rows[2], |x, y, z| {
        (x & y) | (x & z) | (y & z)
    });
    for r in [X(1), X(2), X(3), Data(7)] {
        assert_eq!(s.read_row(r), maj, "row {r}");
    }
}

#[test]
fn thirty_two_bit_add_on_full_rows() {
    let mut c = Controller::new(DramGeometry::default());
    let mut rng = Rng::new(4);
    let n = 8192usize;
    let av: Vec<u32> = (0..n).map(|_| rng.next_u64() as u32).collect();
    let bv: Vec<u32> = (0..n).map(|_| rng.next_u64() as u32).collect();
    let (mut ar, mut br, mut sr) = (vec![], vec![], vec![]);
    for bit in 0..32u16 {
        let mut pa = BitRow::zeros(n);
        let mut pb = BitRow::zeros(n);
        for e in 0..n {
            pa.set(e, (av[e] >> bit) & 1 == 1);
            pb.set(e, (bv[e] >> bit) & 1 == 1);
        }
        c.write_row(0, 0, Data(bit), &pa);
        c.write_row(0, 0, Data(100 + bit), &pb);
        ar.push(Data(bit));
        br.push(Data(100 + bit));
        sr.push(Data(200 + bit));
    }
    let stats = c.add_planes(0, 0, &ar, &br, &sr, Data(300));
    assert_eq!(stats.aaps, 7 * 32);
    // spot-check 200 random elements
    for _ in 0..200 {
        let e = rng.below(n as u64) as usize;
        let mut got = 0u32;
        for (bit, s) in sr.iter().enumerate() {
            got |= (c.read_row(0, 0, *s).get(e) as u32) << bit;
        }
        assert_eq!(got, av[e].wrapping_add(bv[e]), "elem {e}");
    }
}

#[test]
fn energy_and_time_scale_with_sequences() {
    let mut c = Controller::new(DramGeometry::tiny());
    let mut rng = Rng::new(5);
    let a = BitRow::random(c.geometry.cols, &mut rng);
    c.write_row(0, 0, Data(0), &a);
    c.write_row(0, 0, Data(1), &a);
    let xnor = c.exec_op(BulkOp::Xnor2, 0, 0, &[Data(0), Data(1)], Data(2));
    let xor = c.exec_op(BulkOp::Xor2, 0, 0, &[Data(0), Data(1)], Data(3));
    // XOR routes through DCC: exactly one extra AAP
    assert_eq!(xor.aaps, xnor.aaps + 1);
    assert!(xor.time_ns > xnor.time_ns);
    assert!(xor.energy_pj > xnor.energy_pj);
}
