//! Concurrency stress for the capacity-governed residency layer: routed
//! submits from several producer threads with work stealing ON while a
//! chaos thread migrates, evicts, and replicates regions concurrently.
//!
//! Invariants checked across a fixed seed matrix (`util::prop::check_seeds`):
//!   * no lost request — every submitted request completes with the
//!     correct result — and no double execution (a receiver never yields
//!     a second response);
//!   * metrics counters sum exactly: completed = merged requests =
//!     verified responses, hits + misses cover every routed request, and
//!     admission tickets reconcile with requeue-returned tickets;
//!   * copy charges land on the executing device (a device that executed
//!     nothing is charged nothing);
//!   * footprint on every device stays within its `DeviceCapacity` at
//!     every instant (polled mid-flight by the chaos thread), and the
//!     registry bookkeeping stays internally consistent;
//!   * a resident lookup racing with eviction yields the *defined*
//!     `RouteError::Evicted` signal — producers recover by re-register +
//!     resubmit (requeue), never by panicking.

mod common;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use common::{bits_of, host_op};
use drim::cluster::{
    CapacityConfig, ClusterConfig, ClusterRequest, CoalesceConfig, DeviceCapacity,
    DeviceId, DrimCluster, EvictOutcome, EvictionPolicy, RebalanceConfig, RegionId,
    ReplicationPolicy, RouteError,
};
use drim::coordinator::Payload;
use drim::isa::program::BulkOp;
use drim::util::bitrow::BitRow;
use drim::util::prop;
use drim::util::rng::Rng;

const DEVICES: usize = 4;
const PRODUCERS: usize = 4;
const PER_PRODUCER: usize = 24;
const BITS: usize = 1024;
const CHAOS_OPS: usize = 400;
const SEEDS: [u64; 3] = [0xA11CE, 0xB0B5EED, 0xC0FFEE];

#[test]
fn routed_stress_with_stealing_migration_and_eviction() {
    prop::check_seeds("cluster_stress", &SEEDS, |rng| {
        stress_once(rng.next_u64(), false)
    });
}

/// The same stress with the fleet's *own* machinery switched on: the
/// background rebalancer sweeping every millisecond and opportunistic
/// wave coalescing staging the sub-wave routed requests. One seed keeps
/// CI time bounded; the invariants are identical — maintenance sweeps and
/// staging may never lose, duplicate, or corrupt a request.
#[test]
fn stress_with_background_rebalancer_and_coalescing() {
    prop::check_seeds("cluster_stress_bg", &[0xFACADE], |rng| {
        stress_once(rng.next_u64(), true)
    });
}

fn stress_once(seed: u64, background: bool) -> Result<(), String> {
    let cap = DeviceCapacity::of_bits((6 * BITS) as u64);
    let cluster = DrimCluster::new(ClusterConfig {
        capacity: CapacityConfig {
            capacity: cap,
            policy: EvictionPolicy::Lru,
        },
        steal: true,
        coalesce: if background {
            CoalesceConfig::opportunistic()
        } else {
            CoalesceConfig::off()
        },
        rebalance: background.then(|| RebalanceConfig {
            policy: ReplicationPolicy::default(),
            epoch: std::time::Duration::from_millis(1),
            min_queue_depth: 0,
        }),
        ..ClusterConfig::tiny(DEVICES)
    });
    let max_id = AtomicU64::new(0);
    let requeues = AtomicU64::new(0);
    let verified = AtomicU64::new(0);
    let errors: Mutex<Vec<String>> = Mutex::new(Vec::new());

    std::thread::scope(|scope| {
        // chaos: migrate/evict/replicate recently issued regions while
        // routed traffic flows, polling the capacity bound every step
        {
            let cluster = &cluster;
            let max_id = &max_id;
            let errors = &errors;
            scope.spawn(move || {
                let mut rng = Rng::new(seed ^ 0xC4A05);
                for _ in 0..CHAOS_OPS {
                    let hi = max_id.load(Ordering::Relaxed);
                    let region = RegionId(rng.below(hi + 1));
                    let dev = DeviceId(rng.below(DEVICES as u64) as usize);
                    match rng.below(3) {
                        0 => {
                            let _ = cluster.registry().migrate(region, dev);
                        }
                        1 => {
                            let _: EvictOutcome = cluster.registry().evict_from(region, dev);
                        }
                        _ => {
                            let _ = cluster.registry().replicate(region, dev);
                        }
                    }
                    // the capacity bound must hold at every instant
                    for d in 0..DEVICES {
                        let bits = cluster.registry().resident_bits_on(DeviceId(d));
                        if bits > cap.resident_bits {
                            errors
                                .lock()
                                .unwrap()
                                .push(format!("dev{d} over capacity mid-flight: {bits}"));
                            return;
                        }
                    }
                    if let Err(e) = cluster.registry().check_invariants() {
                        errors.lock().unwrap().push(e);
                        return;
                    }
                    std::thread::yield_now();
                }
            });
        }
        for p in 0..PRODUCERS {
            let cluster = &cluster;
            let max_id = &max_id;
            let requeues = &requeues;
            let verified = &verified;
            let errors = &errors;
            scope.spawn(move || {
                let mut rng = Rng::new(seed ^ (p as u64 + 1).wrapping_mul(0x9E37));
                let fail = |msg: String| errors.lock().unwrap().push(msg);
                for i in 0..PER_PRODUCER {
                    let a = BitRow::random(BITS, &mut rng);
                    let owner = DeviceId((p + i) % DEVICES);
                    let mut attempts = 0;
                    loop {
                        // (re-)register; LRU always makes room for a
                        // BITS-sized region
                        let r = match cluster
                            .try_register_resident(owner, Payload::Bits(a.clone()))
                        {
                            Ok(r) => r,
                            Err(e) => {
                                fail(format!("producer {p} register refused: {e}"));
                                return;
                            }
                        };
                        max_id.fetch_max(r.0, Ordering::Relaxed);
                        let req = ClusterRequest::resident(BulkOp::Not, vec![r]);
                        match cluster.submit_routed_blocking(req) {
                            Ok(rx) => {
                                let resp = match rx.recv() {
                                    Ok(resp) => resp,
                                    Err(_) => {
                                        fail(format!("producer {p} channel closed"));
                                        return;
                                    }
                                };
                                if *bits_of(&resp.inner.result) != host_op(BulkOp::Not, &[&a]) {
                                    fail(format!("producer {p} request {i}: wrong result"));
                                    return;
                                }
                                // exactly-once: a second response on the
                                // same receiver would be a double execution
                                if rx.try_recv().is_ok() {
                                    fail(format!("producer {p} request {i}: double response"));
                                    return;
                                }
                                verified.fetch_add(1, Ordering::Relaxed);
                                break;
                            }
                            Err(RouteError::Evicted(_)) => {
                                // the defined shed/requeue signal
                                requeues.fetch_add(1, Ordering::Relaxed);
                                attempts += 1;
                                if attempts > 50 {
                                    fail(format!("producer {p} request {i}: requeue livelock"));
                                    return;
                                }
                            }
                            Err(e) => {
                                // UnknownRegion here would mean eviction
                                // skipped its tombstone; Admission means a
                                // blocking path shed — both are bugs
                                fail(format!("producer {p} request {i}: undefined error {e:?}"));
                                return;
                            }
                        }
                    }
                }
            });
        }
    });

    let found = errors.into_inner().unwrap();
    if !found.is_empty() {
        return Err(found.join("; "));
    }
    let requeues = requeues.load(Ordering::Relaxed);
    let verified = verified.load(Ordering::Relaxed);
    let snap = cluster.shutdown();
    let total = (PRODUCERS * PER_PRODUCER) as u64;
    if verified != total {
        return Err(format!("verified {verified} of {total} requests"));
    }
    // counters sum exactly: every verified request completed exactly once
    if snap.completed != total {
        return Err(format!("completed {} != {total}", snap.completed));
    }
    if snap.merged.requests != total {
        return Err(format!("device requests {} != {total}", snap.merged.requests));
    }
    if snap.resident_hits + snap.resident_misses != total {
        return Err(format!(
            "hits {} + misses {} != {total}",
            snap.resident_hits, snap.resident_misses
        ));
    }
    // admission reconciles: a requeued attempt may have won (and
    // returned) a ticket before resolution observed the eviction
    if snap.admitted < total || snap.admitted - total > requeues {
        return Err(format!(
            "admitted {} outside [{total}, {total} + {requeues}]",
            snap.admitted
        ));
    }
    if snap.shed != 0 {
        return Err(format!("blocking submits shed {} requests", snap.shed));
    }
    // copy charges land on the executing device only (with the background
    // rebalancer on, replication streams legitimately charge destination
    // devices that never executed a request — skip the check there)
    if !background {
        for (d, per) in snap.per_device.iter().enumerate() {
            if per.requests == 0 && snap.copy_ns_per_device[d] != 0 {
                return Err(format!(
                    "dev{d} executed nothing but was charged {} ns of copy",
                    snap.copy_ns_per_device[d]
                ));
            }
        }
    }
    // the final state still satisfies every registry invariant
    cluster.registry().check_invariants()?;
    Ok(())
}

/// A queued request holds materialized payloads, not handles: evicting its
/// region after admission must not dangle it — and the *next* use of the
/// stale handle gets the defined error without burning a ticket.
#[test]
fn eviction_after_submit_does_not_dangle() {
    let cluster = DrimCluster::new(ClusterConfig {
        steal: false,
        capacity: CapacityConfig {
            capacity: DeviceCapacity::of_bits((4 * BITS) as u64),
            policy: EvictionPolicy::Lru,
        },
        ..ClusterConfig::tiny(2)
    });
    let mut rng = Rng::new(71);
    let a = BitRow::random(BITS, &mut rng);
    let r = cluster
        .try_register_resident(DeviceId(1), Payload::Bits(a.clone()))
        .unwrap();
    let rx = cluster
        .submit_routed_blocking(ClusterRequest::resident(BulkOp::Not, vec![r]))
        .unwrap();
    // evict while the request is in flight: it was materialized at
    // resolve time, so it still completes correctly
    assert_eq!(
        cluster.registry().evict_from(r, DeviceId(1)),
        EvictOutcome::RegionEvicted
    );
    let resp = rx.recv().expect("in-flight request survives eviction");
    assert_eq!(*bits_of(&resp.inner.result), host_op(BulkOp::Not, &[&a]));
    // the stale handle now yields the defined error, ticket-free
    match cluster.try_submit_routed(ClusterRequest::resident(BulkOp::Not, vec![r])) {
        Err(RouteError::Evicted(rr)) => assert_eq!(rr, r),
        other => panic!("expected Evicted, got {other:?}"),
    }
    let snap = cluster.shutdown();
    assert_eq!(snap.admitted, 1, "the stale resubmit must not take a ticket");
    assert_eq!(snap.completed, 1);
    assert_eq!(snap.evictions, 1);
}
