//! PJRT integration: the Rust functional simulator and analog mirrors vs
//! the AOT-lowered JAX artifacts. Requires `make artifacts` (skips with a
//! clear message otherwise).
//!
//! PJRT clients are not `Send`, so each test builds its own `Runtime`;
//! a process-wide mutex serializes them (concurrent CPU clients in one
//! process are fragile at teardown).

use std::sync::Mutex;

use drim::analog::montecarlo::run_montecarlo;
use drim::analog::params as P;
use drim::analog::transient;
use drim::controller::Controller;
use drim::dram::command::RowId::*;
use drim::dram::geometry::DramGeometry;
use drim::isa::program::BulkOp;
use drim::runtime::golden::{verify_bulk, BULK_WORDS};
use drim::runtime::Runtime;
use drim::util::bitrow::BitRow;
use drim::util::rng::Rng;

static PJRT_GATE: Mutex<()> = Mutex::new(());

macro_rules! with_rt {
    ($rt:ident) => {
        let _gate = PJRT_GATE.lock().unwrap_or_else(|p| p.into_inner());
        let mut $rt = match Runtime::load_default() {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("skipping PJRT test (run `make artifacts`): {e}");
                return;
            }
        };
    };
}

#[test]
fn all_bulk_artifacts_match_functional_sim() {
    with_rt!(rt);
    let mut c = Controller::new(DramGeometry::tiny());
    let mut rng = Rng::new(1);
    let cols = c.geometry.cols;
    for (op, name) in [
        (BulkOp::Xnor2, "xnor2"),
        (BulkOp::Xor2, "xor2"),
        (BulkOp::And2, "and2"),
        (BulkOp::Or2, "or2"),
        (BulkOp::Nand2, "nand2"),
        (BulkOp::Nor2, "nor2"),
        (BulkOp::Maj3, "maj3"),
        (BulkOp::Min3, "min3"),
    ] {
        let operands: Vec<BitRow> = (0..op.arity())
            .map(|_| BitRow::random(cols, &mut rng))
            .collect();
        for (i, o) in operands.iter().enumerate() {
            c.write_row(0, 0, Data(i as u16), o);
        }
        let srcs = [Data(0), Data(1), Data(2)];
        c.exec_op(op, 0, 0, &srcs[..op.arity()], Data(5));
        let result = c.read_row(0, 0, Data(5));
        let refs: Vec<&BitRow> = operands.iter().collect();
        let bits = verify_bulk(&mut rt, name, &refs, &result)
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(bits, cols);
    }
}

#[test]
fn not_artifact_matches_dcc_not() {
    with_rt!(rt);
    let mut c = Controller::new(DramGeometry::tiny());
    let mut rng = Rng::new(2);
    let a = BitRow::random(c.geometry.cols, &mut rng);
    c.write_row(0, 0, Data(0), &a);
    c.exec_op(BulkOp::Not, 0, 0, &[Data(0)], Data(5));
    let result = c.read_row(0, 0, Data(5));
    verify_bulk(&mut rt, "not1", &[&a], &result).unwrap();
}

#[test]
fn bitplane_add_artifact_matches_controller_adder() {
    with_rt!(rt);
    // artifact shape: 32 planes × 2048 i32 words = 65 536 elements;
    // simulate a slice of it on the controller and compare plane-wise
    let words = 2048usize;
    let mut rng = Rng::new(3);
    let a: Vec<i32> = (0..32 * words).map(|_| rng.next_u64() as i32).collect();
    let b: Vec<i32> = (0..32 * words).map(|_| rng.next_u64() as i32).collect();
    let cin = vec![0i32; words];
    let (sum, carry) = rt.bitplane_add(&a, &b, &cin).unwrap();

    // controller: same planes over a cols=2048*32 geometry is too wide for
    // one sub-array; use the first 8192 bit-lanes (256 words per plane)
    let lanes = 8192usize;
    let wpl = lanes / 32;
    let mut c = Controller::new(DramGeometry::default());
    let (mut ar, mut br, mut sr) = (vec![], vec![], vec![]);
    for bit in 0..32usize {
        let pa: Vec<u32> = a[bit * words..bit * words + wpl]
            .iter()
            .map(|&x| x as u32)
            .collect();
        let pb: Vec<u32> = b[bit * words..bit * words + wpl]
            .iter()
            .map(|&x| x as u32)
            .collect();
        c.write_row(0, 0, Data(bit as u16), &BitRow::from_u32_lanes(lanes, &pa));
        c.write_row(
            0,
            0,
            Data(100 + bit as u16),
            &BitRow::from_u32_lanes(lanes, &pb),
        );
        ar.push(Data(bit as u16));
        br.push(Data(100 + bit as u16));
        sr.push(Data(200 + bit as u16));
    }
    c.add_planes(0, 0, &ar, &br, &sr, Data(300));
    for bit in 0..32usize {
        let got = c.read_row(0, 0, sr[bit]).to_u32_lanes();
        let want = &sum[bit * words..bit * words + wpl];
        for w in 0..wpl {
            assert_eq!(got[w] as i32, want[w], "plane {bit} word {w}");
        }
    }
    let got_c = c.read_row(0, 0, Data(300)).to_u32_lanes();
    for w in 0..wpl {
        assert_eq!(got_c[w] as i32, carry[w], "carry word {w}");
    }
}

#[test]
fn mc_artifact_statistically_matches_rust_mirror() {
    with_rt!(rt);
    for (i, &v) in [0.10f64, 0.20].iter().enumerate() {
        let (de, te, dn, tn) = rt.mc_variation([42, i as u32], v as f32).unwrap();
        let jax_dra = 100.0 * de as f64 / dn as f64;
        let jax_tra = 100.0 * te as f64 / tn as f64;
        let r = run_montecarlo(v, P::MC_TRIALS, 99 + i as u64);
        // Monte-Carlo agreement: within 1.5 percentage points
        assert!(
            (jax_dra - r.dra_pct()).abs() < 1.5,
            "±{v}: DRA jax {jax_dra:.2} vs rust {:.2}",
            r.dra_pct()
        );
        assert!(
            (jax_tra - r.tra_pct()).abs() < 2.0,
            "±{v}: TRA jax {jax_tra:.2} vs rust {:.2}",
            r.tra_pct()
        );
    }
}

#[test]
fn mc_artifact_reproduces_table3_shape() {
    with_rt!(rt);
    let mut last_dra = 0.0;
    for (i, &v) in [0.05f32, 0.10, 0.15, 0.20, 0.30].iter().enumerate() {
        let (de, te, dn, tn) = rt.mc_variation([7, i as u32], v).unwrap();
        let dra = 100.0 * de as f64 / dn as f64;
        let tra = 100.0 * te as f64 / tn as f64;
        assert!(dra <= tra, "±{v}: DRA {dra} > TRA {tra}");
        assert!(dra >= last_dra - 0.01, "DRA not monotone at ±{v}");
        last_dra = dra;
        if v <= 0.10 {
            assert!(dra < 0.05, "DRA must be clean at ±{v}: {dra}");
        }
    }
}

#[test]
fn transient_artifact_matches_rust_mirror_pointwise() {
    with_rt!(rt);
    let flat = rt
        .transient([[0., 0.], [0., 1.], [1., 0.], [1., 1.]])
        .unwrap();
    let steps = P::transient_steps();
    assert_eq!(flat.len(), 4 * steps * 4);
    for (ci, (_, _, w)) in transient::all_cases().into_iter().enumerate() {
        for (t, s) in w.iter().enumerate().step_by(37) {
            for k in 0..4 {
                let jax = flat[(ci * steps + t) * 4 + k] as f64;
                assert!(
                    (jax - s[k]).abs() < 2e-3,
                    "case {ci} t {t} ch {k}: jax {jax} rust {}",
                    s[k]
                );
            }
        }
    }
}

#[test]
fn golden_check_detects_corruption() {
    with_rt!(rt);
    let mut rng = Rng::new(9);
    let a = BitRow::random(BULK_WORDS * 32, &mut rng);
    let b = BitRow::random(BULK_WORDS * 32, &mut rng);
    let mut result = BitRow::zeros(a.len());
    result.apply2(&a, &b, |x, y| !(x ^ y));
    assert!(verify_bulk(&mut rt, "xnor2", &[&a, &b], &result).is_ok());
    // flip one bit — the checker must catch it
    let flip = (rng.below(result.len() as u64)) as usize;
    let v = result.get(flip);
    result.set(flip, !v);
    assert!(verify_bulk(&mut rt, "xnor2", &[&a, &b], &result).is_err());
}
