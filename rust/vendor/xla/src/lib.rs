//! Offline stub of the `xla` (xla-rs) PJRT bindings.
//!
//! This crate exists so the DRIM workspace links with no registry or XLA
//! installation present. It mirrors exactly the type/function surface
//! `src/runtime/client.rs` compiles against. The entry point
//! [`PjRtClient::cpu`] always returns [`Error::BackendUnavailable`], so
//! every artifact-backed path (golden checks, `--jax` flags, the PJRT
//! integration tests) degrades to its documented "artifacts missing /
//! runtime unavailable" fallback instead of failing at link time.
//!
//! Swapping the `xla` path dependency in rust/Cargo.toml for the real
//! xla-rs re-enables artifact execution with no source changes.

use std::fmt;

#[derive(Debug)]
pub enum Error {
    /// The stub backend: no PJRT plugin is linked into this build.
    BackendUnavailable,
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "PJRT backend not available (offline xla stub; link xla-rs to enable)"
        )
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types the artifact I/O uses (`Literal::vec1` / `Literal::to_vec`).
pub trait ArrayElement: Copy {}
impl ArrayElement for i32 {}
impl ArrayElement for u32 {}
impl ArrayElement for f32 {}
impl ArrayElement for f64 {}
impl ArrayElement for i64 {}

/// Host-side literal. The stub holds no data: every literal originates
/// from a client that cannot be constructed, so the accessors below are
/// unreachable in practice and error defensively.
pub struct Literal(());

impl Literal {
    pub fn vec1<T: ArrayElement>(_data: &[T]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::BackendUnavailable)
    }

    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>> {
        Err(Error::BackendUnavailable)
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::BackendUnavailable)
    }
}

impl AsRef<Literal> for Literal {
    fn as_ref(&self) -> &Literal {
        self
    }
}

pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::BackendUnavailable)
    }
}

pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::BackendUnavailable)
    }
}

pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L: AsRef<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::BackendUnavailable)
    }
}

pub struct PjRtClient(());

impl PjRtClient {
    /// Always fails in the stub: there is no PJRT plugin in this build.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::BackendUnavailable)
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::BackendUnavailable)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_unavailable() {
        let e = PjRtClient::cpu().err().expect("stub must not build a client");
        assert!(e.to_string().contains("PJRT backend not available"));
    }

    #[test]
    fn literal_construction_is_infallible_but_accessors_error() {
        let l = Literal::vec1(&[1i32, 2, 3]);
        assert!(l.reshape(&[3]).is_err());
        assert!(l.to_vec::<i32>().is_err());
        assert!(l.to_tuple().is_err());
    }
}
