//! Minimal offline shim of the `anyhow` crate, covering the API surface
//! this repository uses: [`Error`], [`Result`], the [`anyhow!`] / [`bail!`]
//! macros, and the [`Context`] extension trait for `Result` and `Option`.
//!
//! Semantics match real `anyhow` for these paths: errors are opaque,
//! `Display`-driven, and context wraps the cause as `"context: cause"`.
//! The shim exists only so the workspace builds with no registry access;
//! replacing the path dependency with crates.io `anyhow = "1"` requires no
//! source changes.

use std::fmt;

/// Opaque error: a display chain (outermost context first).
///
/// Deliberately does *not* implement `std::error::Error`, exactly like the
/// real `anyhow::Error` — that is what makes the blanket
/// `From<E: std::error::Error>` impl coherent.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error {
            chain: vec![m.to_string()],
        }
    }

    /// Prepend a context layer (outermost first, as in `anyhow`).
    pub fn context<C: fmt::Display>(mut self, c: C) -> Self {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The layers of the error, outermost context first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // anyhow's Debug is the display chain with causes listed; a single
        // joined line is enough for test output here.
        write!(f, "{}", self.chain.join(": "))
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// `.context(..)` / `.with_context(..)` on `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn context_chains_outermost_first() {
        let r: Result<()> = Err(io_err()).with_context(|| "reading manifest");
        let e = r.unwrap_err();
        assert_eq!(e.to_string(), "reading manifest: gone");
        assert_eq!(e.root_cause(), "gone");
    }

    #[test]
    fn option_context() {
        let r: Result<i32> = None.context("missing ]");
        assert_eq!(r.unwrap_err().to_string(), "missing ]");
        let ok: Result<i32> = Some(3).context("unused");
        assert_eq!(ok.unwrap(), 3);
    }

    #[test]
    fn bail_and_question_mark() {
        fn f(fail: bool) -> Result<u32> {
            if fail {
                bail!("bad value {}", 7);
            }
            let n: u32 = "42".parse()?; // std error converts via From
            Ok(n)
        }
        assert_eq!(f(true).unwrap_err().to_string(), "bad value 7");
        assert_eq!(f(false).unwrap(), 42);
    }
}
